//! Fraction-based variant specs for evaluation.
//!
//! The paper's budgets are *fractions of the live sequence*: at step S,
//! Loki selects k = k_f·S tokens. The compiled graphs take the absolute
//! budget `j_sel` as a runtime input, so the eval harnesses rebuild the
//! `DecodeVariant` each step from the current cache length. (The serving
//! engine, by contrast, deliberately uses a fixed budget — a production
//! latency-SLO choice.)

use crate::runtime::{DecodeVariant, Manifest};

#[derive(Clone, Debug, PartialEq)]
pub enum VariantSpec {
    Full,
    Loki { k_f: f64, d_f: f64 },
    /// Exact-TopK = Loki ranking with the full basis.
    TopK { k_f: f64 },
    H2o { k_f: f64 },
    PcaAttn { d_f: f64 },
    /// Per-layer d_f (Fig. 15's variable policy), shared k_f.
    LokiVariable { k_f: f64, d_per_layer: Vec<usize> },
}

impl VariantSpec {
    pub fn label(&self) -> String {
        match self {
            VariantSpec::Full => "full".into(),
            VariantSpec::Loki { k_f, d_f } => format!("loki k={k_f} d={d_f}"),
            VariantSpec::TopK { k_f } => format!("exact-topk k={k_f}"),
            VariantSpec::H2o { k_f } => format!("h2o k={k_f}"),
            VariantSpec::PcaAttn { d_f } => format!("pcaattn d={d_f}"),
            VariantSpec::LokiVariable { k_f, .. } => format!("loki-var k={k_f}"),
        }
    }

    /// Build the concrete decode call for the current live length.
    pub fn materialize(&self, man: &Manifest, live: usize) -> DecodeVariant {
        let budget = |k_f: f64| ((live as f64 * k_f).ceil() as i32).max(1);
        match self {
            VariantSpec::Full => DecodeVariant::Full,
            VariantSpec::Loki { k_f, d_f } => {
                if let DecodeVariant::Loki { d_mask, .. } =
                    DecodeVariant::loki_fractions(man, 1.0, *d_f)
                {
                    DecodeVariant::Loki { d_mask, j_sel: budget(*k_f) }
                } else {
                    unreachable!()
                }
            }
            VariantSpec::TopK { k_f } => {
                if let DecodeVariant::Loki { d_mask, .. } =
                    DecodeVariant::loki_fractions(man, 1.0, 1.0)
                {
                    DecodeVariant::Loki { d_mask, j_sel: budget(*k_f) }
                } else {
                    unreachable!()
                }
            }
            VariantSpec::H2o { k_f } => DecodeVariant::H2o { j_sel: budget(*k_f).max(2) },
            VariantSpec::PcaAttn { d_f } => DecodeVariant::pcaattn_fraction(man, *d_f),
            VariantSpec::LokiVariable { k_f, d_per_layer } => {
                if let DecodeVariant::Loki { d_mask, .. } =
                    DecodeVariant::loki_variable(man, 1.0, d_per_layer)
                {
                    DecodeVariant::Loki { d_mask, j_sel: budget(*k_f) }
                } else {
                    unreachable!()
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::artifacts_dir;

    #[test]
    fn budgets_scale_with_live_length() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let man = Manifest::load(&dir).unwrap();
        let spec = VariantSpec::Loki { k_f: 0.25, d_f: 0.25 };
        let a = spec.materialize(&man, 100);
        let b = spec.materialize(&man, 400);
        match (a, b) {
            (DecodeVariant::Loki { j_sel: ja, d_mask: da },
             DecodeVariant::Loki { j_sel: jb, d_mask: db }) => {
                assert_eq!(ja, 25);
                assert_eq!(jb, 100);
                assert_eq!(da, db);
                let kept: f32 = da.iter().sum();
                assert_eq!(kept as usize, man.model.n_layers * man.model.head_dim / 4);
            }
            _ => panic!("wrong variant"),
        }
    }
}
