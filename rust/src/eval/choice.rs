//! Multiple-choice scoring by continuation log-prob (the LM-harness
//! discipline): each choice's bytes are teacher-forced after the prompt
//! and the summed log-prob decides the prediction.

use anyhow::Result;

use crate::model::log_prob;
use crate::runtime::{DecodeRequest, RuntimeStack};

use super::variant_spec::VariantSpec;

#[derive(Clone, Debug)]
pub struct ChoiceOutcome {
    pub predicted: usize,
    pub correct: usize,
    pub logprobs: Vec<f64>,
}

impl ChoiceOutcome {
    pub fn is_correct(&self) -> bool {
        self.predicted == self.correct
    }
}

/// Score one item: the prompt is prefilled once per lane (all lanes share
/// the prompt), then each lane teacher-forces a different choice. Choices
/// beyond the batch bucket are scored in extra passes.
pub fn score_choices_batch(
    stack: &RuntimeStack,
    pca: &str,
    variant: &VariantSpec,
    prompt: &[i32],
    choices: &[Vec<i32>],
    correct: usize,
) -> Result<ChoiceOutcome> {
    let bucket = stack.manifest.pick_batch_bucket(choices.len());
    let mut logprobs = vec![0.0f64; choices.len()];
    // Clamp over-long prompts to the largest prefill bucket, keeping the
    // tail (recency carries the queries for our tasks... except the
    // needle may sit anywhere — clamping is reported by the caller).
    let max_p = *stack.manifest.prefill_buckets.iter().max().unwrap();
    let prompt = if prompt.len() > max_p { &prompt[prompt.len() - max_p..] } else { prompt };

    for (chunk_i, chunk) in choices.chunks(bucket).enumerate() {
        let prompts: Vec<Vec<i32>> = chunk.iter().map(|_| prompt.to_vec()).collect();
        let (id, mut logits) = stack.prefill(pca, &prompts)?;
        let max_len = chunk.iter().map(|c| c.len()).max().unwrap_or(0);
        let lanes = stack.state_batch(id).unwrap_or(chunk.len());
        for p in 0..max_len {
            for (lane, choice) in chunk.iter().enumerate() {
                if p < choice.len() {
                    logprobs[chunk_i * bucket + lane] +=
                        log_prob(&logits[lane], choice[p] as usize) as f64;
                }
            }
            if p + 1 == max_len {
                break;
            }
            let mut tokens: Vec<i32> = chunk
                .iter()
                .map(|c| if p < c.len() { c[p] } else { 0 })
                .collect();
            tokens.resize(lanes, 0);
            let dv = variant.materialize(&stack.manifest, prompt.len() + p + 1);
            logits = stack.decode(&DecodeRequest { state: id, variant: dv, tokens })?;
        }
        stack.free(id);
    }
    let predicted = argmax_logprob(&logprobs);
    Ok(ChoiceOutcome { predicted, correct, logprobs })
}

/// Argmax over choice log-probs in IEEE total order, ties broken toward
/// the lower index — the same discipline as `linalg::topk`. The old
/// `partial_cmp().unwrap()` panicked on a NaN log-prob (one degenerate
/// logit row aborted the whole eval); `total_cmp` ranks NaN above +inf,
/// so a NaN lane is *selected* (and graded wrong) rather than fatal.
pub fn argmax_logprob(logprobs: &[f64]) -> usize {
    logprobs
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1).then_with(|| b.0.cmp(&a.0)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::argmax_logprob;

    #[test]
    fn picks_the_max_logprob() {
        assert_eq!(argmax_logprob(&[-2.0, -0.25, -1.0]), 1);
    }

    #[test]
    fn ties_break_toward_the_lower_index() {
        assert_eq!(argmax_logprob(&[-1.0, -0.5, -0.5]), 1);
        assert_eq!(argmax_logprob(&[0.0, 0.0, 0.0]), 0);
    }

    #[test]
    fn nan_is_ranked_not_fatal() {
        // Regression: this input used to panic via partial_cmp().unwrap().
        assert_eq!(argmax_logprob(&[f64::NAN, -0.5]), 0, "+NaN tops total order");
        assert_eq!(argmax_logprob(&[-f64::NAN, -1.0]), 1, "-NaN bottoms total order");
    }

    #[test]
    fn empty_input_defaults_to_zero() {
        assert_eq!(argmax_logprob(&[]), 0);
    }
}
