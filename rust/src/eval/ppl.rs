//! Perplexity under a decode variant (Table 2 / Fig. 3 machinery).

use anyhow::Result;

use crate::model::log_prob;
use crate::runtime::{DecodeRequest, RuntimeStack};

use super::variant_spec::VariantSpec;

#[derive(Clone, Debug)]
pub struct PplReport {
    pub variant: String,
    pub pca: String,
    pub n_docs: usize,
    pub n_tokens: usize,
    pub nll_sum: f64,
    pub wall_s: f64,
}

impl PplReport {
    pub fn perplexity(&self) -> f64 {
        (self.nll_sum / self.n_tokens.max(1) as f64).exp()
    }
}

/// Teacher-forced perplexity of `docs` (equal lengths) under `variant`.
///
/// Docs are packed into gangs of the largest batch bucket; each step feeds
/// the true next byte and scores it against the previous step's logits.
/// The first `seed_len` tokens are prefilled (full attention, matching the
/// paper's setup where approximation applies to generation steps) and
/// excluded from the NLL.
#[allow(clippy::disallowed_methods)] // genuine wall measurement: eval throughput reporting
pub fn perplexity(
    stack: &RuntimeStack,
    pca: &str,
    variant: &VariantSpec,
    docs: &[Vec<i32>],
    seed_len: usize,
    max_tokens_per_doc: usize,
) -> Result<PplReport> {
    let t0 = std::time::Instant::now();
    let bucket = *stack.manifest.batch_buckets.iter().max().unwrap();
    let mut nll_sum = 0.0f64;
    let mut n_tokens = 0usize;

    for gang_docs in docs.chunks(bucket) {
        let lanes = gang_docs.len();
        let doc_len = gang_docs
            .iter()
            .map(|d| d.len())
            .min()
            .unwrap_or(0)
            .min(seed_len + max_tokens_per_doc)
            .min(stack.manifest.model.max_len - 1);
        if doc_len <= seed_len {
            continue;
        }
        let prompts: Vec<Vec<i32>> = gang_docs.iter().map(|d| d[..seed_len].to_vec()).collect();
        let (id, mut logits) = stack.prefill(pca, &prompts)?;
        // Position p: logits predict byte at p; feed byte at p, get logits
        // for p+1.
        for p in seed_len..doc_len {
            for (lane, doc) in gang_docs.iter().enumerate() {
                nll_sum -= log_prob(&logits[lane], doc[p] as usize) as f64;
                n_tokens += 1;
            }
            if p + 1 == doc_len {
                break;
            }
            let mut tokens: Vec<i32> = gang_docs.iter().map(|d| d[p]).collect();
            tokens.resize(stack.state_batch(id).unwrap_or(lanes), 0);
            // Budgets are fractions of the *live* length, per the paper.
            let dv = variant.materialize(&stack.manifest, p + 1);
            logits = stack.decode(&DecodeRequest { state: id, variant: dv, tokens })?;
        }
        stack.free(id);
    }
    Ok(PplReport {
        variant: variant.label(),
        pca: pca.to_string(),
        n_docs: docs.len(),
        n_tokens,
        nll_sum,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}
