//! Quality evaluation through the compiled runtime: perplexity and
//! multiple-choice accuracy under any decode variant.
//!
//! Both harnesses run *stepwise teacher-forced decode* so the sparse
//! attention under test is exercised at every generation position —
//! exactly how the paper evaluates Loki/H2O (the method applies during
//! generation, not during prefill).

pub mod choice;
pub mod ppl;
pub mod variant_spec;

pub use choice::{score_choices_batch, ChoiceOutcome};
pub use ppl::{perplexity, PplReport};
pub use variant_spec::VariantSpec;
