//! Structured trace events for the flight recorder.
//!
//! Every payload is plain-old-data (ids, counts, byte totals) so events
//! are `Copy`, recording never allocates, and the JSONL/Chrome exporters
//! can serialize without touching engine types. Request class is the
//! priority index (`Priority::index()` — 0 interactive, 1 batch) and
//! finish reasons are the `FinishCode` mirror of
//! `coordinator::request::FinishReason`.

/// Terminal outcome of a generation, mirrored from
/// `coordinator::request::FinishReason` so `obs` stays a leaf module.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishCode {
    MaxTokens,
    StopToken,
    CacheFull,
    EngineShutdown,
    Shed,
}

impl FinishCode {
    pub fn name(self) -> &'static str {
        match self {
            FinishCode::MaxTokens => "max_tokens",
            FinishCode::StopToken => "stop_token",
            FinishCode::CacheFull => "cache_full",
            FinishCode::EngineShutdown => "engine_shutdown",
            FinishCode::Shed => "shed",
        }
    }
}

/// KV-pool lifecycle events, emitted by `kvpool::TableSet` /
/// `kvpool::TieredKvPool` into a bounded `PoolEventLog` and drained by
/// the engine into the flight recorder each scheduling round.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolEvent {
    /// A sequence was admitted with `blocks` physical blocks, `shared`
    /// of which were prefix-cache hits (ref-count bumps, not copies).
    Alloc { seq: u64, blocks: u32, shared: u32 },
    /// A sequence released `blocks` table entries (physical frees
    /// happen per-block as refcounts hit zero).
    Free { seq: u64, blocks: u32 },
    /// Mid-decode growth granted `blocks` new blocks (may be partial).
    Grow { seq: u64, blocks: u32 },
    /// Partial preemption truncated a tail: `freed` blocks returned,
    /// `kept_blocks`/`kept_len` retained for cheap resume.
    Truncate { seq: u64, freed: u32, kept_blocks: u32, kept_len: u32 },
    /// Tiered pool gather touched `pages` non-resident pages, moving
    /// `bytes` across the tier boundary (`TierStats::bytes_faulted`).
    Fault { seq: u64, pages: u32, bytes: u64 },
    /// Tier budget enforcement demoted `pages` hot pages to cold.
    Demotion { pages: u32 },
    /// A content-addressed prefix block drained its last reference and
    /// was physically freed: the chain hash `hash` no longer resolves in
    /// the radix tree. The frontend forwards these to the router so
    /// per-replica affinity mirrors drop the dead entry.
    PrefixReleased { hash: u64 },
}

impl PoolEvent {
    pub fn name(self) -> &'static str {
        match self {
            PoolEvent::Alloc { .. } => "pool_alloc",
            PoolEvent::Free { .. } => "pool_free",
            PoolEvent::Grow { .. } => "pool_grow",
            PoolEvent::Truncate { .. } => "pool_truncate",
            PoolEvent::Fault { .. } => "pool_fault",
            PoolEvent::Demotion { .. } => "tier_demotion",
            PoolEvent::PrefixReleased { .. } => "prefix_released",
        }
    }
}

/// What happened. Request lifecycle events carry the request id; the
/// conservation invariants in `obs::export` are defined over them.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    RequestAdmitted { id: u64, class: u8, prompt_len: u32, max_new: u32 },
    RequestShed { id: u64, class: u8, predicted_ttft_ms: f64 },
    RequestRejected { id: u64 },
    PrefillStart { id: u64, lane: u32, tokens: u32 },
    /// One executed chunk of a chunked prefill: the lane now holds
    /// `done` of `total` prompt tokens. Emitted strictly inside a
    /// `prefill_start`…`prefill_end` episode with `done` increasing —
    /// the interleaving the trace-check lifecycle verifies.
    PrefillChunk { id: u64, lane: u32, done: u32, total: u32 },
    PrefillEnd { id: u64, lane: u32, tokens: u32 },
    /// Padding-lane blank re-prefill at the physical cache bound —
    /// carries no request id (the lane holds no request) but is real
    /// backend work, so it is traced and billed like any prefill.
    LaneReset { lane: u32 },
    FirstToken { id: u64, ttft_steps: u64 },
    PreemptFull { id: u64, lane: u32, freed_blocks: u32 },
    PreemptPartial { id: u64, lane: u32, freed_blocks: u32, kept_len: u32 },
    Resume { id: u64, lane: u32, recomputed_tokens: u32, kept_tokens: u32 },
    Finish { id: u64, reason: FinishCode, tokens: u32 },
    /// One per decode iteration: batch occupancy, backlog, pool
    /// headroom, and the analytic score-path data movement of this step
    /// (`attnsim::score_path_bytes` summed over busy lanes) against the
    /// exact-attention baseline — the paper's reduced-data-movement
    /// claim as a per-step observable.
    SchedRound {
        busy_lanes: u32,
        queue_depth: u32,
        free_blocks: u32,
        score_bytes_moved: u64,
        score_bytes_exact: u64,
    },
    Pool(PoolEvent),
}

impl EventKind {
    /// Stable snake_case name used by the JSONL schema and the checker.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::RequestAdmitted { .. } => "request_admitted",
            EventKind::RequestShed { .. } => "request_shed",
            EventKind::RequestRejected { .. } => "request_rejected",
            EventKind::PrefillStart { .. } => "prefill_start",
            EventKind::PrefillChunk { .. } => "prefill_chunk",
            EventKind::PrefillEnd { .. } => "prefill_end",
            EventKind::LaneReset { .. } => "lane_reset",
            EventKind::FirstToken { .. } => "first_token",
            EventKind::PreemptFull { .. } => "preempt_full",
            EventKind::PreemptPartial { .. } => "preempt_partial",
            EventKind::Resume { .. } => "resume",
            EventKind::Finish { .. } => "finish",
            EventKind::SchedRound { .. } => "sched_round",
            EventKind::Pool(p) => p.name(),
        }
    }

    /// Request id for lifecycle events; `None` for engine/pool events.
    pub fn request_id(&self) -> Option<u64> {
        match *self {
            EventKind::RequestAdmitted { id, .. }
            | EventKind::RequestShed { id, .. }
            | EventKind::RequestRejected { id }
            | EventKind::PrefillStart { id, .. }
            | EventKind::PrefillChunk { id, .. }
            | EventKind::PrefillEnd { id, .. }
            | EventKind::FirstToken { id, .. }
            | EventKind::PreemptFull { id, .. }
            | EventKind::PreemptPartial { id, .. }
            | EventKind::Resume { id, .. }
            | EventKind::Finish { id, .. } => Some(id),
            EventKind::SchedRound { .. } | EventKind::LaneReset { .. } | EventKind::Pool(_) => {
                None
            }
        }
    }
}

/// A recorded event: monotone sequence number, clock timestamp
/// (milliseconds — step-derived under `EngineClock::Steps`, wall
/// elapsed under `Wall`), decode-step counter at record time, payload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TraceEvent {
    pub seq: u64,
    pub ts_ms: f64,
    pub step: u64,
    pub kind: EventKind,
}

/// Bounded side-channel for pool events. The KV tables have no clock
/// and no recorder; they push here (preallocated, never reallocates)
/// and the engine drains into the flight recorder, stamping timestamps.
#[derive(Clone, Debug)]
pub struct PoolEventLog {
    buf: Vec<PoolEvent>,
    cap: usize,
    dropped: u64,
}

/// Events per scheduling round are bounded by gang size; 4096 between
/// drains is generous.
pub const POOL_EVENT_LOG_CAPACITY: usize = 4096;

impl Default for PoolEventLog {
    fn default() -> Self {
        Self::with_capacity(POOL_EVENT_LOG_CAPACITY)
    }
}

impl PoolEventLog {
    pub fn with_capacity(cap: usize) -> Self {
        let cap = cap.max(1);
        Self { buf: Vec::with_capacity(cap), cap, dropped: 0 }
    }

    /// Record an event; silently counts drops past capacity (a full log
    /// between drains means a drain cadence bug, not a reason to grow).
    pub fn push(&mut self, ev: PoolEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    /// Drain accumulated events in push order, keeping the allocation.
    pub fn drain(&mut self) -> std::vec::Drain<'_, PoolEvent> {
        self.buf.drain(..)
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn event_names_and_ids() {
        let e = EventKind::RequestAdmitted { id: 7, class: 0, prompt_len: 3, max_new: 4 };
        assert_eq!(e.name(), "request_admitted");
        assert_eq!(e.request_id(), Some(7));
        let s = EventKind::SchedRound {
            busy_lanes: 1,
            queue_depth: 0,
            free_blocks: 9,
            score_bytes_moved: 10,
            score_bytes_exact: 20,
        };
        assert_eq!(s.name(), "sched_round");
        assert_eq!(s.request_id(), None);
        let p = EventKind::Pool(PoolEvent::Fault { seq: 1, pages: 2, bytes: 64 });
        assert_eq!(p.name(), "pool_fault");
        assert_eq!(p.request_id(), None);
    }

    #[test]
    fn pool_log_bounded_and_drains_in_order() {
        let mut log = PoolEventLog::with_capacity(2);
        log.push(PoolEvent::Alloc { seq: 1, blocks: 2, shared: 0 });
        log.push(PoolEvent::Free { seq: 1, blocks: 2 });
        log.push(PoolEvent::Demotion { pages: 1 });
        assert_eq!(log.len(), 2);
        assert_eq!(log.dropped(), 1);
        let evs: Vec<_> = log.drain().collect();
        assert_eq!(evs[0], PoolEvent::Alloc { seq: 1, blocks: 2, shared: 0 });
        assert_eq!(evs[1], PoolEvent::Free { seq: 1, blocks: 2 });
        assert!(log.is_empty());
        // Drain keeps capacity: the next push does not drop.
        log.push(PoolEvent::Demotion { pages: 1 });
        assert_eq!(log.dropped(), 1);
    }
}
