//! Streaming log-bucketed histogram: constant-memory percentiles for
//! the engine's metrics hot paths.
//!
//! `linalg::stats::Summary` keeps every sample in a `Vec` — exact, but
//! unbounded: a serving engine that runs for days grows its latency
//! summaries without limit. `StreamingHist` replaces it in
//! `EngineMetrics`/`ClassMetrics` with a fixed array of geometric
//! buckets (`BUCKETS_PER_OCTAVE` per power of two, spanning
//! `MIN_TRACKED..` up to ~1.8e10) plus exact running `count/sum/sumsq/
//! min/max`. Consequences:
//!
//! * `mean()` and `sum()` are **bit-identical** to `Summary` — same
//!   left-to-right f64 accumulation in push order. Deterministic bench
//!   outputs that report means (e.g. the e2e smoke JSON) do not move.
//! * `percentile(p)` is approximate: the geometric midpoint of the
//!   bucket holding the rank-`p` sample, clamped to `[min, max]`. The
//!   relative error is at most one bucket width (`2^(1/4)` ≈ 19%),
//!   which is the resolution contract tested against exact `Summary`
//!   percentiles in `rust/tests/obs_trace.rs`.
//! * Non-positive, sub-`MIN_TRACKED`, and NaN samples land in a
//!   dedicated underflow bucket; their percentile representative is
//!   `min` (exact running min ignores NaN).
//!
//! The experiment harnesses keep using `Summary` where exact order
//! statistics matter; this type is for long-lived serving metrics.

/// Geometric bucket resolution: 4 buckets per octave → relative bucket
/// width `2^(1/4)` ≈ 1.19.
pub const BUCKETS_PER_OCTAVE: usize = 4;

/// Smallest positively-tracked value; anything at or below it (and any
/// NaN) counts in the underflow bucket. 1 ns when the unit is seconds.
pub const MIN_TRACKED: f64 = 1e-9;

/// 64 octaves above `MIN_TRACKED` ≈ 1.8e10 — wide enough for seconds,
/// milliseconds, steps, and occupancy fractions alike.
const OCTAVES: usize = 64;
const NBUCKETS: usize = OCTAVES * BUCKETS_PER_OCTAVE;

/// Bucket index for a value, or `None` for the underflow bucket.
fn bucket_index(v: f64) -> Option<usize> {
    // `!(v > MIN_TRACKED)` is deliberately NaN-inclusive.
    if !(v > MIN_TRACKED) {
        return None;
    }
    let idx = ((v / MIN_TRACKED).log2() * BUCKETS_PER_OCTAVE as f64).floor() as isize;
    Some(idx.clamp(0, NBUCKETS as isize - 1) as usize)
}

/// Lower edge of bucket `i`.
fn bucket_lo(i: usize) -> f64 {
    MIN_TRACKED * 2f64.powf(i as f64 / BUCKETS_PER_OCTAVE as f64)
}

/// Constant-memory p50/p95/p99/max summary. API mirrors
/// `linalg::stats::Summary` so metrics call sites swap types without
/// churn.
#[derive(Clone, Debug)]
pub struct StreamingHist {
    count: u64,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
    under: u64,
    buckets: Vec<u64>,
}

impl Default for StreamingHist {
    fn default() -> Self {
        Self {
            count: 0,
            sum: 0.0,
            sumsq: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            under: 0,
            buckets: vec![0; NBUCKETS],
        }
    }
}

impl StreamingHist {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.sumsq += v * v;
        // f64::min/max skip NaN operands, so a NaN sample cannot poison
        // the exact extrema (it still counts toward `count`/underflow).
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        match bucket_index(v) {
            None => self.under += 1,
            Some(i) => self.buckets[i] += 1,
        }
    }

    pub fn count(&self) -> usize {
        self.count as usize
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn std_dev(&self) -> f64 {
        if self.count < 2 {
            return 0.0;
        }
        let n = self.count as f64;
        let var = (self.sumsq - self.sum * self.sum / n) / (n - 1.0);
        var.max(0.0).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Approximate percentile (p in [0, 100]): geometric midpoint of
    /// the bucket holding the nearest-rank sample, clamped to the exact
    /// `[min, max]` so p0/p100 and one-bucket histograms stay tight.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let p = p.clamp(0.0, 100.0);
        if p <= 0.0 {
            return self.min;
        }
        if p >= 100.0 {
            return self.max;
        }
        let target = ((p / 100.0) * (self.count - 1) as f64).round() as u64;
        let mut cum = self.under;
        if target < cum {
            return self.min;
        }
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if target < cum {
                let lo = bucket_lo(i);
                let hi = bucket_lo(i + 1);
                return (lo * hi).sqrt().clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Same rendering contract as `Summary::display`.
    pub fn display(&self) -> String {
        format!(
            "{:.3} ± {:.3} [p50 {:.3}, p95 {:.3}, p99 {:.3}] n={}",
            self.mean(),
            self.std_dev(),
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
            self.count()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::stats::Summary;

    #[test]
    fn mean_sum_bit_identical_to_summary() {
        let mut h = StreamingHist::new();
        let mut s = Summary::new();
        let mut x = 0.317f64;
        for _ in 0..500 {
            x = (x * 1.7 + 0.13) % 5.0;
            h.push(x);
            s.push(x);
        }
        // Same push order, same left-to-right accumulation: exact.
        assert_eq!(h.sum(), s.sum());
        assert_eq!(h.mean(), s.mean());
        assert_eq!(h.count(), s.count());
        assert_eq!(h.min(), s.min());
        assert_eq!(h.max(), s.max());
    }

    #[test]
    fn empty_hist_is_quiet() {
        let h = StreamingHist::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.percentile(50.0), 0.0);
        assert_eq!(h.std_dev(), 0.0);
    }

    #[test]
    fn percentile_single_value_is_exact() {
        let mut h = StreamingHist::new();
        h.push(0.042);
        for p in [0.0, 50.0, 95.0, 99.0, 100.0] {
            // min==max clamp collapses the bucket midpoint to the value.
            assert_eq!(h.percentile(p), 0.042);
        }
    }

    #[test]
    fn percentile_within_one_bucket_of_exact() {
        let mut h = StreamingHist::new();
        let mut s = Summary::new();
        let mut seed = 0x9e3779b97f4a7c15u64;
        for _ in 0..2000 {
            seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let u = (seed >> 11) as f64 / (1u64 << 53) as f64;
            // Log-uniform over ~6 decades: stresses many buckets.
            let v = 10f64.powf(-4.0 + 6.0 * u);
            h.push(v);
            s.push(v);
        }
        let width = 2f64.powf(1.0 / BUCKETS_PER_OCTAVE as f64);
        for p in [50.0, 95.0, 99.0] {
            let approx = h.percentile(p);
            let exact = s.percentile(p);
            let ratio = approx / exact;
            assert!(
                ratio < width * 1.01 && ratio > 1.0 / (width * 1.01),
                "p{p}: approx {approx} vs exact {exact} (ratio {ratio})"
            );
        }
        assert_eq!(h.percentile(0.0), s.min());
        assert_eq!(h.percentile(100.0), s.max());
    }

    #[test]
    fn underflow_and_nan_count_but_do_not_poison() {
        let mut h = StreamingHist::new();
        h.push(0.0);
        h.push(-1.0);
        h.push(f64::NAN);
        h.push(2.0);
        assert_eq!(h.count(), 4);
        assert_eq!(h.min(), -1.0);
        assert_eq!(h.max(), 2.0);
        // Underflow representative is the exact min.
        assert_eq!(h.percentile(10.0), -1.0);
        assert_eq!(h.percentile(100.0), 2.0);
    }

    #[test]
    fn display_format_matches_summary_contract() {
        let mut h = StreamingHist::new();
        h.push(1.0);
        h.push(1.0);
        let d = h.display();
        assert!(d.starts_with("1.000 ± 0.000 [p50 "), "{d}");
        assert!(d.ends_with("n=2"), "{d}");
    }
}
