//! Flight recorder: a bounded ring buffer of `TraceEvent`s.
//!
//! Default-on and cheap enough to leave on: recording is one enum store
//! into a `Vec` that grows lazily up to `DEFAULT_TRACE_CAPACITY` and
//! then overwrites in ring order — **zero allocations per event at
//! steady state**. When the ring wraps, the oldest events are lost and
//! `dropped()` counts them; the conservation checker refuses to certify
//! a truncated trace, so tests and CI size the buffer (or the run) to
//! fit.

use super::event::{EventKind, TraceEvent};

/// Default ring capacity (events). At ~56 bytes/event this is ~1 MB
/// fully grown — enough for the entire e2e scenario suite without a
/// single drop.
pub const DEFAULT_TRACE_CAPACITY: usize = 16_384;

#[derive(Clone, Debug)]
pub struct FlightRecorder {
    buf: Vec<TraceEvent>,
    cap: usize,
    /// Next overwrite position once `buf.len() == cap`.
    head: usize,
    /// Total events ever recorded == next sequence number.
    seq: u64,
    dropped: u64,
}

impl Default for FlightRecorder {
    fn default() -> Self {
        Self::with_capacity(DEFAULT_TRACE_CAPACITY)
    }
}

impl FlightRecorder {
    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::new(), cap: cap.max(1), head: 0, seq: 0, dropped: 0 }
    }

    /// Record one event. The caller supplies the timestamp so the clock
    /// policy (Steps vs Wall) lives with the engine, not here.
    pub fn record(&mut self, ts_ms: f64, step: u64, kind: EventKind) {
        let ev = TraceEvent { seq: self.seq, ts_ms, step, kind };
        self.seq += 1;
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Events currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total events ever recorded, including overwritten ones.
    pub fn recorded(&self) -> u64 {
        self.seq
    }

    /// Events lost to ring overwrite.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Iterate surviving events in sequence order (oldest first).
    pub fn iter(&self) -> impl Iterator<Item = &TraceEvent> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(id: u64) -> EventKind {
        EventKind::RequestRejected { id }
    }

    #[test]
    fn records_in_order_below_capacity() {
        let mut r = FlightRecorder::with_capacity(8);
        for i in 0..5 {
            r.record(i as f64, i, ev(i));
        }
        assert_eq!(r.len(), 5);
        assert_eq!(r.recorded(), 5);
        assert_eq!(r.dropped(), 0);
        let seqs: Vec<u64> = r.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let mut r = FlightRecorder::with_capacity(4);
        for i in 0..10 {
            r.record(i as f64, i, ev(i));
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.recorded(), 10);
        assert_eq!(r.dropped(), 6);
        // Survivors are the last 4, still in sequence order.
        let seqs: Vec<u64> = r.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        for (e, want) in r.iter().zip([6u64, 7, 8, 9]) {
            assert_eq!(e.kind, ev(want));
        }
    }

    #[test]
    fn capacity_floor_is_one() {
        let mut r = FlightRecorder::with_capacity(0);
        r.record(0.0, 0, ev(0));
        r.record(1.0, 0, ev(1));
        assert_eq!(r.len(), 1);
        assert_eq!(r.iter().next().unwrap().seq, 1);
    }
}
