//! Live metrics snapshot + exposition formats.
//!
//! The engine publishes a `StatsSnapshot` into a shared `StatsHub`
//! (mutex-wrapped `Option`) once per scheduling round; the server's
//! `"stats"` protocol command reads the latest one and renders it as
//! JSON plus a Prometheus-style text exposition. The snapshot is a flat
//! plain-old-data struct built by `EngineMetrics::snapshot`, so taking
//! it never blocks the scheduler on I/O and readers never see a
//! half-updated state.

use std::sync::{Arc, Mutex};

use super::hist::StreamingHist;
use crate::util::json::{self, Json};

/// Number of per-turn TTFT buckets carried by the snapshot: turns 0, 1
/// and 2 exactly, with index 3 folding in every turn ≥ 3. The engine's
/// `EngineMetrics` sizes its per-turn histograms off this same constant.
pub const TURN_BUCKETS: usize = 4;

/// Compact view of one histogram for exposition.
#[derive(Clone, Copy, Debug, Default)]
pub struct HistSnap {
    pub count: u64,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl HistSnap {
    pub fn of(h: &StreamingHist) -> Self {
        Self {
            count: h.count() as u64,
            mean: h.mean(),
            p50: h.percentile(50.0),
            p95: h.percentile(95.0),
            p99: h.percentile(99.0),
            max: if h.count() == 0 { 0.0 } else { h.max() },
        }
    }

    fn to_json(self) -> Json {
        json::obj(vec![
            ("count", json::num(self.count as f64)),
            ("mean", json::num(self.mean)),
            ("p50", json::num(self.p50)),
            ("p95", json::num(self.p95)),
            ("p99", json::num(self.p99)),
            ("max", json::num(self.max)),
        ])
    }

    /// Count-weighted merge across replicas. Means and maxima merge
    /// exactly; the percentiles are count-weighted averages of the
    /// per-replica percentiles — an *approximation* (exact fleet
    /// quantiles would need the underlying histograms), good enough for
    /// a dashboard roll-up and clearly better than showing one replica.
    fn merged(parts: impl Iterator<Item = HistSnap>) -> HistSnap {
        let mut out = HistSnap::default();
        let mut wsum = [0.0f64; 4]; // mean, p50, p95, p99 accumulators
        for h in parts {
            if h.count == 0 {
                continue;
            }
            let w = h.count as f64;
            out.count += h.count;
            wsum[0] += h.mean * w;
            wsum[1] += h.p50 * w;
            wsum[2] += h.p95 * w;
            wsum[3] += h.p99 * w;
            out.max = out.max.max(h.max);
        }
        if out.count > 0 {
            let n = out.count as f64;
            out.mean = wsum[0] / n;
            out.p50 = wsum[1] / n;
            out.p95 = wsum[2] / n;
            out.p99 = wsum[3] / n;
        }
        out
    }
}

/// Per-class (interactive/batch) counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ClassSnap {
    pub done: u64,
    pub preemptions: u64,
    pub shed: u64,
    pub deadline_hits: u64,
    pub deadline_misses: u64,
    pub ttft: HistSnap,
}

/// One engine-wide metrics snapshot, published per scheduling round.
#[derive(Clone, Debug, Default)]
pub struct StatsSnapshot {
    pub uptime_s: f64,
    pub throughput_tok_s: f64,
    pub requests_in: u64,
    pub requests_done: u64,
    pub requests_rejected: u64,
    pub requests_shed: u64,
    pub tokens_generated: u64,
    pub prefills: u64,
    pub prefill_chunks: u64,
    pub lane_reset_prefills: u64,
    pub decode_steps: u64,
    pub preemptions: u64,
    pub resumes: u64,
    pub queue_depth: u64,
    pub busy_lanes: u64,
    pub pool_blocks_total: u64,
    pub pool_blocks_in_use: u64,
    pub pool_blocks_peak: u64,
    pub goodput_tok_per_step: f64,
    pub wasted_work_tokens: u64,
    /// Radix-tree gauges: live prefix nodes and cumulative admission
    /// hits resolved by the tree.
    pub radix_nodes: u64,
    pub radix_hit_blocks: u64,
    /// Turn ≥ 1 prefix probe / hit tallies (denominator / numerator of
    /// [`StatsSnapshot::turn_cache_hit_rate`], kept raw so the fleet
    /// merge stays exact).
    pub turn_ref_blocks: u64,
    pub turn_shared_blocks: u64,
    /// Charged-domain TTFT per conversation turn (0, 1, 2, 3+).
    pub turn_ttft_ms: [HistSnap; TURN_BUCKETS],
    pub ttft: HistSnap,
    pub e2e: HistSnap,
    pub queue_wait: HistSnap,
    pub decode_step: HistSnap,
    pub trace_recorded: u64,
    pub trace_dropped: u64,
    pub classes: [ClassSnap; 2],
}

/// Shared slot the engine writes and the server reads. `None` until the
/// engine's first scheduling round.
pub type StatsHub = Arc<Mutex<Option<StatsSnapshot>>>;

pub fn new_hub() -> StatsHub {
    Arc::new(Mutex::new(None))
}

const CLASS_NAMES: [&str; 2] = ["interactive", "batch"];

impl StatsSnapshot {
    /// Roll per-replica snapshots up into one fleet view for the
    /// sharded frontend's `{"stats": true}` reply: counters and gauges
    /// sum, `uptime_s` is the slowest replica's (they started together;
    /// under the Steps clock the busiest one has ticked furthest),
    /// `goodput_tok_per_step` is re-derived decode-step-weighted, and
    /// histograms merge count-weighted (see [`HistSnap::merged`] for
    /// the percentile caveat). Empty input → default snapshot.
    pub fn merged(parts: &[StatsSnapshot]) -> StatsSnapshot {
        let mut out = StatsSnapshot::default();
        let mut goodput_weighted = 0.0f64;
        for p in parts {
            out.uptime_s = out.uptime_s.max(p.uptime_s);
            out.throughput_tok_s += p.throughput_tok_s;
            out.requests_in += p.requests_in;
            out.requests_done += p.requests_done;
            out.requests_rejected += p.requests_rejected;
            out.requests_shed += p.requests_shed;
            out.tokens_generated += p.tokens_generated;
            out.prefills += p.prefills;
            out.prefill_chunks += p.prefill_chunks;
            out.lane_reset_prefills += p.lane_reset_prefills;
            out.decode_steps += p.decode_steps;
            out.preemptions += p.preemptions;
            out.resumes += p.resumes;
            out.queue_depth += p.queue_depth;
            out.busy_lanes += p.busy_lanes;
            out.pool_blocks_total += p.pool_blocks_total;
            out.pool_blocks_in_use += p.pool_blocks_in_use;
            out.pool_blocks_peak += p.pool_blocks_peak;
            goodput_weighted += p.goodput_tok_per_step * p.decode_steps as f64;
            out.wasted_work_tokens += p.wasted_work_tokens;
            out.radix_nodes += p.radix_nodes;
            out.radix_hit_blocks += p.radix_hit_blocks;
            out.turn_ref_blocks += p.turn_ref_blocks;
            out.turn_shared_blocks += p.turn_shared_blocks;
            out.trace_recorded += p.trace_recorded;
            out.trace_dropped += p.trace_dropped;
            for (oc, pc) in out.classes.iter_mut().zip(p.classes.iter()) {
                oc.done += pc.done;
                oc.preemptions += pc.preemptions;
                oc.shed += pc.shed;
                oc.deadline_hits += pc.deadline_hits;
                oc.deadline_misses += pc.deadline_misses;
            }
        }
        if out.decode_steps > 0 {
            out.goodput_tok_per_step = goodput_weighted / out.decode_steps as f64;
        }
        out.ttft = HistSnap::merged(parts.iter().map(|p| p.ttft));
        out.e2e = HistSnap::merged(parts.iter().map(|p| p.e2e));
        out.queue_wait = HistSnap::merged(parts.iter().map(|p| p.queue_wait));
        out.decode_step = HistSnap::merged(parts.iter().map(|p| p.decode_step));
        for i in 0..2 {
            out.classes[i].ttft = HistSnap::merged(parts.iter().map(|p| p.classes[i].ttft));
        }
        for i in 0..TURN_BUCKETS {
            out.turn_ttft_ms[i] = HistSnap::merged(parts.iter().map(|p| p.turn_ttft_ms[i]));
        }
        out
    }

    /// Conversational prefix-hit rate: turn ≥ 1 shared over probed full
    /// blocks; 1.0 when no follow-up turn ever probed (nothing was
    /// missable — same convention as the engine's prefix hit rate).
    pub fn turn_cache_hit_rate(&self) -> f64 {
        if self.turn_ref_blocks == 0 {
            return 1.0;
        }
        self.turn_shared_blocks as f64 / self.turn_ref_blocks as f64
    }

    /// Structured JSON form (the `"stats"` reply body).
    pub fn to_json(&self) -> Json {
        let classes = (0..2).map(|i| {
            let c = &self.classes[i];
            json::obj(vec![
                ("class", json::s(CLASS_NAMES[i])),
                ("done", json::num(c.done as f64)),
                ("preemptions", json::num(c.preemptions as f64)),
                ("shed", json::num(c.shed as f64)),
                ("deadline_hits", json::num(c.deadline_hits as f64)),
                ("deadline_misses", json::num(c.deadline_misses as f64)),
                ("ttft_s", c.ttft.to_json()),
            ])
        });
        json::obj(vec![
            ("uptime_s", json::num(self.uptime_s)),
            ("throughput_tok_s", json::num(self.throughput_tok_s)),
            ("requests_in", json::num(self.requests_in as f64)),
            ("requests_done", json::num(self.requests_done as f64)),
            ("requests_rejected", json::num(self.requests_rejected as f64)),
            ("requests_shed", json::num(self.requests_shed as f64)),
            ("tokens_generated", json::num(self.tokens_generated as f64)),
            ("prefills", json::num(self.prefills as f64)),
            ("prefill_chunks", json::num(self.prefill_chunks as f64)),
            ("lane_reset_prefills", json::num(self.lane_reset_prefills as f64)),
            ("decode_steps", json::num(self.decode_steps as f64)),
            ("preemptions", json::num(self.preemptions as f64)),
            ("resumes", json::num(self.resumes as f64)),
            ("queue_depth", json::num(self.queue_depth as f64)),
            ("busy_lanes", json::num(self.busy_lanes as f64)),
            ("pool_blocks_total", json::num(self.pool_blocks_total as f64)),
            ("pool_blocks_in_use", json::num(self.pool_blocks_in_use as f64)),
            ("pool_blocks_peak", json::num(self.pool_blocks_peak as f64)),
            ("goodput_tok_per_step", json::num(self.goodput_tok_per_step)),
            ("wasted_work_tokens", json::num(self.wasted_work_tokens as f64)),
            ("radix_nodes", json::num(self.radix_nodes as f64)),
            ("radix_hit_blocks", json::num(self.radix_hit_blocks as f64)),
            ("turn_ref_blocks", json::num(self.turn_ref_blocks as f64)),
            ("turn_shared_blocks", json::num(self.turn_shared_blocks as f64)),
            ("turn_cache_hit_rate", json::num(self.turn_cache_hit_rate())),
            (
                "turn_ttft_ms",
                Json::Arr(self.turn_ttft_ms.iter().map(|h| h.to_json()).collect()),
            ),
            ("ttft_s", self.ttft.to_json()),
            ("e2e_s", self.e2e.to_json()),
            ("queue_wait_s", self.queue_wait.to_json()),
            ("decode_step_s", self.decode_step.to_json()),
            ("trace_recorded", json::num(self.trace_recorded as f64)),
            ("trace_dropped", json::num(self.trace_dropped as f64)),
            ("classes", Json::Arr(classes.collect())),
        ])
    }

    /// Prometheus text exposition (counters + gauges + summary
    /// quantiles), scrapable via the `"stats"` command's `"prom"` field.
    pub fn prometheus(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: f64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        };
        counter("loki_requests_total", "Requests admitted to the engine queue.", self.requests_in as f64);
        counter("loki_requests_done_total", "Requests completed.", self.requests_done as f64);
        counter("loki_requests_rejected_total", "Requests rejected (cache full).", self.requests_rejected as f64);
        counter("loki_requests_shed_total", "Requests shed by predictive admission.", self.requests_shed as f64);
        counter("loki_tokens_generated_total", "Decode tokens produced.", self.tokens_generated as f64);
        counter("loki_prefills_total", "Prefill calls.", self.prefills as f64);
        counter("loki_prefill_chunks_total", "Chunked-prefill chunks executed.", self.prefill_chunks as f64);
        counter("loki_lane_reset_prefills_total", "Padding-lane blank re-prefills.", self.lane_reset_prefills as f64);
        counter("loki_decode_steps_total", "Decode iterations.", self.decode_steps as f64);
        counter("loki_preemptions_total", "Lane preemptions.", self.preemptions as f64);
        counter("loki_resumes_total", "Preempted requests resumed.", self.resumes as f64);
        counter("loki_wasted_work_tokens_total", "Missed-deadline plus recomputed tokens.", self.wasted_work_tokens as f64);
        counter("loki_radix_hit_blocks_total", "Admission prefix blocks resolved by the radix tree.", self.radix_hit_blocks as f64);
        counter("loki_trace_events_total", "Flight-recorder events recorded.", self.trace_recorded as f64);
        counter("loki_trace_dropped_total", "Flight-recorder events lost to ring overwrite.", self.trace_dropped as f64);
        let mut gauge = |name: &str, help: &str, v: f64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} gauge");
            let _ = writeln!(out, "{name} {v}");
        };
        gauge("loki_uptime_seconds", "Engine uptime (clock-routed: steps under the deterministic twin).", self.uptime_s);
        gauge("loki_throughput_tokens_per_second", "Tokens per second of uptime.", self.throughput_tok_s);
        gauge("loki_queue_depth", "Pending requests.", self.queue_depth as f64);
        gauge("loki_busy_lanes", "Lanes currently decoding.", self.busy_lanes as f64);
        gauge("loki_pool_blocks_in_use", "KV pool blocks in use.", self.pool_blocks_in_use as f64);
        gauge("loki_pool_blocks_total", "KV pool capacity in blocks.", self.pool_blocks_total as f64);
        gauge("loki_goodput_tokens_per_step", "Deadline-hit tokens per decode step.", self.goodput_tok_per_step);
        gauge("loki_radix_nodes", "Live radix-tree prefix nodes.", self.radix_nodes as f64);
        gauge("loki_turn_cache_hit_rate", "Turn >= 1 conversational prefix-hit rate.", self.turn_cache_hit_rate());
        let mut summary = |name: &str, help: &str, h: &HistSnap| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} summary");
            let _ = writeln!(out, "{name}{{quantile=\"0.5\"}} {}", h.p50);
            let _ = writeln!(out, "{name}{{quantile=\"0.95\"}} {}", h.p95);
            let _ = writeln!(out, "{name}{{quantile=\"0.99\"}} {}", h.p99);
            let _ = writeln!(out, "{name}_sum {}", h.mean * h.count as f64);
            let _ = writeln!(out, "{name}_count {}", h.count);
        };
        summary("loki_ttft_seconds", "Time to first token.", &self.ttft);
        summary("loki_e2e_seconds", "End-to-end request latency.", &self.e2e);
        summary("loki_queue_wait_seconds", "Queue wait before admission to a lane.", &self.queue_wait);
        summary("loki_decode_step_seconds", "Decode iteration duration.", &self.decode_step);
        for (i, h) in self.turn_ttft_ms.iter().enumerate() {
            let _ = writeln!(out, "loki_turn_ttft_ms_count{{turn=\"{i}\"}} {}", h.count);
            let _ = writeln!(out, "loki_turn_ttft_ms_mean{{turn=\"{i}\"}} {}", h.mean);
        }
        for (i, c) in self.classes.iter().enumerate() {
            let cls = CLASS_NAMES[i];
            let _ = writeln!(out, "loki_class_requests_done_total{{class=\"{cls}\"}} {}", c.done);
            let _ = writeln!(out, "loki_class_preemptions_total{{class=\"{cls}\"}} {}", c.preemptions);
            let _ = writeln!(out, "loki_class_requests_shed_total{{class=\"{cls}\"}} {}", c.shed);
            let _ = writeln!(out, "loki_class_deadline_hits_total{{class=\"{cls}\"}} {}", c.deadline_hits);
            let _ = writeln!(out, "loki_class_deadline_misses_total{{class=\"{cls}\"}} {}", c.deadline_misses);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> StatsSnapshot {
        let mut h = StreamingHist::new();
        h.push(0.1);
        h.push(0.2);
        StatsSnapshot {
            uptime_s: 2.0,
            throughput_tok_s: 8.0,
            requests_in: 4,
            requests_done: 3,
            requests_shed: 1,
            tokens_generated: 16,
            decode_steps: 16,
            ttft: HistSnap::of(&h),
            ..Default::default()
        }
    }

    #[test]
    fn json_is_well_formed() {
        let j = sample().to_json();
        let round = Json::parse(&j.to_string()).unwrap();
        assert_eq!(round.req("requests_in").as_i64(), Some(4));
        assert_eq!(round.req("ttft_s").req("count").as_i64(), Some(2));
        assert_eq!(round.req("classes").as_arr().unwrap().len(), 2);
        assert_eq!(round.req("radix_nodes").as_i64(), Some(0));
        assert_eq!(round.req("turn_ttft_ms").as_arr().unwrap().len(), TURN_BUCKETS);
        // No follow-up turns probed: nothing was missable.
        assert_eq!(round.req("turn_cache_hit_rate").as_i64(), Some(1));
    }

    #[test]
    fn prometheus_has_core_families() {
        let p = sample().prometheus();
        for family in [
            "loki_requests_total 4",
            "loki_tokens_generated_total 16",
            "# TYPE loki_ttft_seconds summary",
            "loki_ttft_seconds{quantile=\"0.5\"}",
            "loki_class_requests_done_total{class=\"interactive\"}",
            "loki_radix_nodes 0",
            "loki_radix_hit_blocks_total 0",
            "loki_turn_cache_hit_rate 1",
            "loki_turn_ttft_ms_count{turn=\"0\"} 0",
        ] {
            assert!(p.contains(family), "missing {family:?} in:\n{p}");
        }
    }

    #[test]
    fn merged_sums_counters_and_weights_hists() {
        let mut a = sample(); // ttft count 2, mean 0.15
        a.goodput_tok_per_step = 1.0;
        let mut b = sample();
        b.requests_in = 6;
        b.decode_steps = 48;
        b.goodput_tok_per_step = 0.5;
        b.uptime_s = 5.0;
        b.radix_nodes = 3;
        b.turn_ref_blocks = 10;
        b.turn_shared_blocks = 4;
        b.turn_ttft_ms[1] = b.ttft;
        let mut h = StreamingHist::new();
        for _ in 0..6 {
            h.push(0.6);
        }
        b.ttft = HistSnap::of(&h);
        let m = StatsSnapshot::merged(&[a, b]);
        assert_eq!(m.requests_in, 10);
        assert_eq!(m.decode_steps, 64);
        assert_eq!(m.uptime_s, 5.0);
        // Step-weighted goodput: (1.0*16 + 0.5*48) / 64.
        assert!((m.goodput_tok_per_step - 0.625).abs() < 1e-12);
        // Count-weighted ttft mean: (0.15*2 + 0.6*6) / 8.
        assert_eq!(m.ttft.count, 8);
        assert!((m.ttft.mean - 0.4875).abs() < 1e-9);
        assert!((m.ttft.max - 0.6).abs() < 1e-12);
        // Radix / turn tallies sum across replicas; turn hists merge
        // bucket-by-bucket.
        assert_eq!(m.radix_nodes, 3);
        assert_eq!(m.turn_ref_blocks, 10);
        assert_eq!(m.turn_shared_blocks, 4);
        assert!((m.turn_cache_hit_rate() - 0.4).abs() < 1e-12);
        assert_eq!(m.turn_ttft_ms[1].count, 2);
        assert_eq!(m.turn_ttft_ms[0].count, 0);
        // Merging one snapshot with an empty one is the identity on
        // counters.
        let solo = StatsSnapshot::merged(&[sample(), StatsSnapshot::default()]);
        assert_eq!(solo.requests_in, sample().requests_in);
        assert_eq!(StatsSnapshot::merged(&[]).requests_in, 0);
    }

    #[test]
    fn hub_starts_empty() {
        let hub = new_hub();
        assert!(hub.lock().unwrap().is_none());
        *hub.lock().unwrap() = Some(sample());
        assert_eq!(hub.lock().unwrap().as_ref().unwrap().requests_in, 4);
    }
}
