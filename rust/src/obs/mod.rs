//! Observability: flight-recorder tracing, streaming histograms, and
//! live metrics exposition for the serving engine.
//!
//! Loki's headline claim is a speedup from *reduced data movement* in
//! the attention score path; end-of-run aggregates can't show where a
//! request's TTFT went or what the KV pool did step by step. This
//! module is the trace substrate:
//!
//! * [`recorder::FlightRecorder`] — bounded ring of structured
//!   [`event::TraceEvent`]s, default-on inside `EngineMetrics`,
//!   zero-allocation-per-event at steady state. Timestamps route
//!   through `EngineClock`, so traces are bit-deterministic under
//!   `SimRuntime`/`Steps` and wall-clocked in serving.
//! * [`hist::StreamingHist`] — constant-memory log-bucketed
//!   histograms replacing `Vec`-backed `Summary` in the metrics hot
//!   paths (exact mean/sum, percentiles within one bucket width).
//! * [`export`] — JSONL + Chrome `trace_event` writers
//!   (`--trace-out`), the FNV-1a fixture hash, and the conservation
//!   checker (`repro trace-check`) that certifies every admitted id
//!   reaches exactly one terminal event.
//! * [`snapshot`] — `StatsSnapshot`/`StatsHub` published by the engine
//!   each scheduling round and served by the `"stats"` protocol
//!   command as JSON + Prometheus text.
//!
//! `obs` is a leaf module: event payloads are plain-old-data, so
//! `kvpool` and `coordinator` can emit events without cyclic coupling.

pub mod event;
pub mod export;
pub mod hist;
pub mod recorder;
pub mod snapshot;

pub use event::{EventKind, FinishCode, PoolEvent, PoolEventLog, TraceEvent};
pub use export::{cross_replica_violations, TraceCheck};
pub use hist::StreamingHist;
pub use recorder::{FlightRecorder, DEFAULT_TRACE_CAPACITY};
pub use snapshot::{new_hub, ClassSnap, HistSnap, StatsHub, StatsSnapshot, TURN_BUCKETS};
