//! Trace export (JSONL + Chrome `trace_event`) and the conservation
//! checker.
//!
//! JSONL schema: line 1 is a meta object
//! `{"trace":"loki-flight-recorder","version":1,"events":…,
//!   "recorded":…,"dropped":…}`, then one object per event with stable
//! keys `seq`/`ts_ms`/`step`/`ev` plus the payload fields of that
//! event kind (`obs::event`). Keys are emitted in sorted order by the
//! writer, so identical traces serialize to identical bytes —
//! `trace_hash` (FNV-1a over those bytes) is how the Steps-clock e2e
//! fixture is pinned.
//!
//! The Chrome file (`chrome.load trace_event` JSON, open in
//! `chrome://tracing` or Perfetto) renders one track per request
//! (admission→terminal span, first-token/preempt/resume instants) and
//! one per lane (prefill→finish/preempt residency spans).
//!
//! The **conservation checker** certifies a complete trace:
//! * no ring drops (a truncated trace proves nothing),
//! * every request id's first event is `request_admitted`, exactly one
//!   terminal event (`finish`/`request_shed`/`request_rejected`)
//!   arrives and nothing follows it,
//! * at most one `first_token` per id, never more resumes than
//!   preempts,
//! * totals conserve: `admitted = finished + shed + rejected` (an id
//!   still in flight is a violation for a drained engine run).

use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use super::event::{EventKind, PoolEvent, TraceEvent};
use super::recorder::FlightRecorder;
use crate::util::json::{self, Json};

/// JSONL object for one event. Payload keys never collide with the
/// envelope (`seq`/`ts_ms`/`step`/`ev`); pool sequence ids are
/// `pool_seq`.
pub fn event_json(ev: &TraceEvent) -> Json {
    let mut fields: Vec<(&str, Json)> = vec![
        ("seq", json::num(ev.seq as f64)),
        ("ts_ms", json::num(ev.ts_ms)),
        ("step", json::num(ev.step as f64)),
        ("ev", json::s(ev.kind.name())),
    ];
    match ev.kind {
        EventKind::RequestAdmitted { id, class, prompt_len, max_new } => {
            fields.push(("id", json::num(id as f64)));
            fields.push(("class", json::num(class as f64)));
            fields.push(("prompt_len", json::num(prompt_len as f64)));
            fields.push(("max_new", json::num(max_new as f64)));
        }
        EventKind::RequestShed { id, class, predicted_ttft_ms } => {
            fields.push(("id", json::num(id as f64)));
            fields.push(("class", json::num(class as f64)));
            fields.push(("predicted_ttft_ms", json::num(predicted_ttft_ms)));
        }
        EventKind::RequestRejected { id } => {
            fields.push(("id", json::num(id as f64)));
        }
        EventKind::PrefillStart { id, lane, tokens } | EventKind::PrefillEnd { id, lane, tokens } => {
            fields.push(("id", json::num(id as f64)));
            fields.push(("lane", json::num(lane as f64)));
            fields.push(("tokens", json::num(tokens as f64)));
        }
        EventKind::PrefillChunk { id, lane, done, total } => {
            fields.push(("id", json::num(id as f64)));
            fields.push(("lane", json::num(lane as f64)));
            fields.push(("done", json::num(done as f64)));
            fields.push(("total", json::num(total as f64)));
        }
        EventKind::LaneReset { lane } => {
            fields.push(("lane", json::num(lane as f64)));
        }
        EventKind::FirstToken { id, ttft_steps } => {
            fields.push(("id", json::num(id as f64)));
            fields.push(("ttft_steps", json::num(ttft_steps as f64)));
        }
        EventKind::PreemptFull { id, lane, freed_blocks } => {
            fields.push(("id", json::num(id as f64)));
            fields.push(("lane", json::num(lane as f64)));
            fields.push(("freed_blocks", json::num(freed_blocks as f64)));
        }
        EventKind::PreemptPartial { id, lane, freed_blocks, kept_len } => {
            fields.push(("id", json::num(id as f64)));
            fields.push(("lane", json::num(lane as f64)));
            fields.push(("freed_blocks", json::num(freed_blocks as f64)));
            fields.push(("kept_len", json::num(kept_len as f64)));
        }
        EventKind::Resume { id, lane, recomputed_tokens, kept_tokens } => {
            fields.push(("id", json::num(id as f64)));
            fields.push(("lane", json::num(lane as f64)));
            fields.push(("recomputed_tokens", json::num(recomputed_tokens as f64)));
            fields.push(("kept_tokens", json::num(kept_tokens as f64)));
        }
        EventKind::Finish { id, reason, tokens } => {
            fields.push(("id", json::num(id as f64)));
            fields.push(("reason", json::s(reason.name())));
            fields.push(("tokens", json::num(tokens as f64)));
        }
        EventKind::SchedRound {
            busy_lanes,
            queue_depth,
            free_blocks,
            score_bytes_moved,
            score_bytes_exact,
        } => {
            fields.push(("busy_lanes", json::num(busy_lanes as f64)));
            fields.push(("queue_depth", json::num(queue_depth as f64)));
            fields.push(("free_blocks", json::num(free_blocks as f64)));
            fields.push(("score_bytes_moved", json::num(score_bytes_moved as f64)));
            fields.push(("score_bytes_exact", json::num(score_bytes_exact as f64)));
        }
        EventKind::Pool(p) => match p {
            PoolEvent::Alloc { seq, blocks, shared } => {
                fields.push(("pool_seq", json::num(seq as f64)));
                fields.push(("blocks", json::num(blocks as f64)));
                fields.push(("shared", json::num(shared as f64)));
            }
            PoolEvent::Free { seq, blocks } => {
                fields.push(("pool_seq", json::num(seq as f64)));
                fields.push(("blocks", json::num(blocks as f64)));
            }
            PoolEvent::Grow { seq, blocks } => {
                fields.push(("pool_seq", json::num(seq as f64)));
                fields.push(("blocks", json::num(blocks as f64)));
            }
            PoolEvent::Truncate { seq, freed, kept_blocks, kept_len } => {
                fields.push(("pool_seq", json::num(seq as f64)));
                fields.push(("freed", json::num(freed as f64)));
                fields.push(("kept_blocks", json::num(kept_blocks as f64)));
                fields.push(("kept_len", json::num(kept_len as f64)));
            }
            PoolEvent::Fault { seq, pages, bytes } => {
                fields.push(("pool_seq", json::num(seq as f64)));
                fields.push(("pages", json::num(pages as f64)));
                fields.push(("bytes", json::num(bytes as f64)));
            }
            PoolEvent::Demotion { pages } => {
                fields.push(("pages", json::num(pages as f64)));
            }
            PoolEvent::PrefixReleased { hash } => {
                // Hex string, not a JSON number: the 64-bit chain hash
                // would lose precision above 2^53 as an f64.
                fields.push(("hash", json::s(&format!("{hash:016x}"))));
            }
        },
    }
    json::obj(fields)
}

/// Serialize the recorder to JSONL (meta line + events, `\n`-separated,
/// trailing newline). Byte-deterministic for a deterministic trace.
pub fn trace_jsonl(rec: &FlightRecorder) -> String {
    let meta = json::obj(vec![
        ("trace", json::s("loki-flight-recorder")),
        ("version", json::num(1.0)),
        ("events", json::num(rec.len() as f64)),
        ("recorded", json::num(rec.recorded() as f64)),
        ("dropped", json::num(rec.dropped() as f64)),
    ]);
    let mut out = meta.to_string();
    out.push('\n');
    for ev in rec.iter() {
        out.push_str(&event_json(ev).to_string());
        out.push('\n');
    }
    out
}

/// FNV-1a 64-bit over raw bytes — the fixture-pinning hash for
/// deterministic Steps-clock traces.
pub fn trace_hash(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Write the JSONL trace to `path`.
pub fn write_jsonl(rec: &FlightRecorder, path: &Path) -> Result<()> {
    std::fs::write(path, trace_jsonl(rec)).with_context(|| format!("write {}", path.display()))
}

/// Sibling path for the Chrome trace: `foo.jsonl` → `foo.chrome.json`.
pub fn chrome_sibling(path: &Path) -> PathBuf {
    let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("trace");
    path.with_file_name(format!("{stem}.chrome.json"))
}

/// Chrome `trace_event` JSON: pid 1 = lane tracks (KV residency spans
/// from prefill to finish/preempt), pid 2 = request tracks (admission →
/// terminal span plus first-token / preempt / resume instants).
pub fn chrome_trace(rec: &FlightRecorder) -> Json {
    const PID_LANES: f64 = 1.0;
    const PID_REQS: f64 = 2.0;
    let us = |ms: f64| ms * 1000.0;
    let mut events: Vec<Json> = vec![
        json::obj(vec![
            ("ph", json::s("M")),
            ("name", json::s("process_name")),
            ("pid", json::num(PID_LANES)),
            ("args", json::obj(vec![("name", json::s("lanes"))])),
        ]),
        json::obj(vec![
            ("ph", json::s("M")),
            ("name", json::s("process_name")),
            ("pid", json::num(PID_REQS)),
            ("args", json::obj(vec![("name", json::s("requests"))])),
        ]),
    ];
    let instant = |name: String, tid: f64, ts_ms: f64| {
        json::obj(vec![
            ("ph", json::s("i")),
            ("s", json::s("t")),
            ("name", json::s(&name)),
            ("pid", json::num(PID_REQS)),
            ("tid", json::num(tid)),
            ("ts", json::num(us(ts_ms))),
        ])
    };
    let span = |name: String, pid: f64, tid: f64, t0: f64, t1: f64, outcome: &str| {
        json::obj(vec![
            ("ph", json::s("X")),
            ("name", json::s(&name)),
            ("pid", json::num(pid)),
            ("tid", json::num(tid)),
            ("ts", json::num(us(t0))),
            ("dur", json::num(us((t1 - t0).max(0.0)))),
            ("args", json::obj(vec![("outcome", json::s(outcome))])),
        ])
    };
    // id → admission timestamp; lane → (occupied-since, id); id → lane.
    // lint:allow(nondet-iter): keyed access only (by request id), never iterated
    let mut admitted_at: HashMap<u64, f64> = HashMap::new();
    // lint:allow(nondet-iter): keyed access only (by lane), never iterated
    let mut lane_busy: HashMap<u32, (f64, u64)> = HashMap::new();
    // lint:allow(nondet-iter): keyed access only (by request id), never iterated
    let mut lane_of: HashMap<u64, u32> = HashMap::new();
    let mut close_lane = |events: &mut Vec<Json>, lane: u32, ts: f64, outcome: &str| {
        if let Some((t0, id)) = lane_busy.remove(&lane) {
            events.push(span(format!("req {id}"), PID_LANES, lane as f64, t0, ts, outcome));
        }
    };
    for ev in rec.iter() {
        let ts = ev.ts_ms;
        match ev.kind {
            EventKind::RequestAdmitted { id, .. } => {
                admitted_at.insert(id, ts);
            }
            EventKind::PrefillStart { id, lane, .. } => {
                lane_busy.insert(lane, (ts, id));
                lane_of.insert(id, lane);
            }
            EventKind::FirstToken { id, .. } => {
                events.push(instant("first_token".into(), id as f64, ts));
            }
            EventKind::PreemptFull { id, lane, .. } => {
                events.push(instant("preempt_full".into(), id as f64, ts));
                close_lane(&mut events, lane, ts, "preempted");
                lane_of.remove(&id);
            }
            EventKind::PreemptPartial { id, lane, .. } => {
                events.push(instant("preempt_partial".into(), id as f64, ts));
                close_lane(&mut events, lane, ts, "preempted");
                lane_of.remove(&id);
            }
            EventKind::Resume { id, .. } => {
                events.push(instant("resume".into(), id as f64, ts));
            }
            EventKind::Finish { id, reason, .. } => {
                if let Some(t0) = admitted_at.remove(&id) {
                    events.push(span(format!("req {id}"), PID_REQS, id as f64, t0, ts, reason.name()));
                }
                if let Some(lane) = lane_of.remove(&id) {
                    close_lane(&mut events, lane, ts, "finished");
                }
            }
            EventKind::RequestShed { id, .. } => {
                if let Some(t0) = admitted_at.remove(&id) {
                    events.push(span(format!("req {id}"), PID_REQS, id as f64, t0, ts, "shed"));
                }
            }
            EventKind::RequestRejected { id } => {
                if let Some(t0) = admitted_at.remove(&id) {
                    events.push(span(format!("req {id}"), PID_REQS, id as f64, t0, ts, "rejected"));
                }
            }
            _ => {}
        }
    }
    json::obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", json::s("ms")),
    ])
}

/// Write the Chrome trace next to the JSONL.
pub fn write_chrome(rec: &FlightRecorder, path: &Path) -> Result<()> {
    std::fs::write(path, chrome_trace(rec).to_string())
        .with_context(|| format!("write {}", path.display()))
}

/// Checker result: lifecycle totals plus every invariant violation
/// found (empty `violations` ⇒ the trace conserves).
#[derive(Debug, Default)]
pub struct TraceCheck {
    pub events: usize,
    pub admitted: u64,
    pub finished: u64,
    pub shed: u64,
    pub rejected: u64,
    pub in_flight: u64,
    /// Every request id this trace admitted — the surface the sharded
    /// frontend's cross-replica check intersects: a request routed to
    /// replica R must live its whole lifecycle on R, so per-replica
    /// traces must admit pairwise-disjoint id sets.
    pub admitted_ids: BTreeSet<u64>,
    pub violations: Vec<String>,
}

impl TraceCheck {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Cross-replica routing invariant over per-replica trace checks: no
/// request id may be admitted by more than one replica (the router
/// owns placement; a double admit means a request leaked across the
/// shard boundary). Returns one violation line per leaked id, in id
/// order; empty ⇒ the shard traces are disjoint.
pub fn cross_replica_violations(labeled: &[(String, TraceCheck)]) -> Vec<String> {
    let mut owners: BTreeMap<u64, Vec<&str>> = BTreeMap::new();
    for (label, chk) in labeled {
        for id in &chk.admitted_ids {
            owners.entry(*id).or_default().push(label.as_str());
        }
    }
    owners
        .iter()
        .filter(|(_, files)| files.len() > 1)
        .map(|(id, files)| {
            format!("id {id}: admitted on multiple replicas ({})", files.join(", "))
        })
        .collect()
}

#[derive(Default)]
struct IdState {
    first_tokens: u32,
    preempts: u32,
    resumes: u32,
    /// Inside a `prefill_start`…`prefill_end` episode (chunk events are
    /// only legal here; a preempt also closes the episode).
    prefill_open: bool,
    /// Last `done` seen from a `prefill_chunk` in the open episode —
    /// chunk progress must be strictly increasing and ≤ total.
    chunk_done: Option<u64>,
    terminal: Option<&'static str>,
}

fn terminal_of(name: &str) -> Option<&'static str> {
    match name {
        "finish" => Some("finish"),
        "request_shed" => Some("request_shed"),
        "request_rejected" => Some("request_rejected"),
        _ => None,
    }
}

/// Core invariant check over `(event_name, request_id, chunk)` triples
/// in trace order (`chunk` is the `(done, total)` payload of
/// `prefill_chunk` events, `None` otherwise). Shared by the in-memory
/// and JSONL paths so both certify the same contract.
fn check_stream<S, I>(items: I, dropped: u64) -> TraceCheck
where
    S: AsRef<str>,
    I: IntoIterator<Item = (S, Option<u64>, Option<(u64, u64)>)>,
{
    let mut out = TraceCheck::default();
    if dropped > 0 {
        out.violations
            .push(format!("{dropped} events lost to ring overwrite; trace is not conservable"));
    }
    // lint:allow(nondet-iter): keyed access; the terminal sweep below iterates in sorted id order
    let mut ids: HashMap<u64, IdState> = HashMap::new();
    for (name, id, chunk) in items {
        out.events += 1;
        let name = name.as_ref();
        let Some(id) = id else { continue };
        if name == "request_admitted" {
            out.admitted_ids.insert(id);
            if ids.insert(id, IdState::default()).is_some() {
                out.violations.push(format!("id {id}: duplicate request_admitted"));
            }
            continue;
        }
        let Some(st) = ids.get_mut(&id) else {
            out.violations.push(format!("id {id}: {name} before request_admitted"));
            continue;
        };
        if let Some(t) = st.terminal {
            out.violations.push(format!("id {id}: {name} after terminal {t}"));
            continue;
        }
        match name {
            "first_token" => {
                st.first_tokens += 1;
                if st.first_tokens > 1 {
                    out.violations.push(format!("id {id}: more than one first_token"));
                }
            }
            "prefill_start" => {
                if st.prefill_open {
                    out.violations
                        .push(format!("id {id}: prefill_start inside an open prefill episode"));
                }
                st.prefill_open = true;
                st.chunk_done = None;
            }
            "prefill_chunk" => {
                if !st.prefill_open {
                    out.violations
                        .push(format!("id {id}: prefill_chunk outside a prefill episode"));
                }
                if let Some((done, total)) = chunk {
                    if done > total {
                        out.violations
                            .push(format!("id {id}: prefill_chunk done {done} > total {total}"));
                    }
                    if let Some(prev) = st.chunk_done {
                        if done <= prev {
                            out.violations.push(format!(
                                "id {id}: prefill_chunk done {done} not after {prev}"
                            ));
                        }
                    }
                    st.chunk_done = Some(done);
                }
            }
            "prefill_end" => {
                if !st.prefill_open {
                    out.violations
                        .push(format!("id {id}: prefill_end without prefill_start"));
                }
                st.prefill_open = false;
                st.chunk_done = None;
            }
            "preempt_full" | "preempt_partial" => {
                st.preempts += 1;
                // A mid-prefill preemption abandons the episode; the
                // re-admission opens a fresh one.
                st.prefill_open = false;
                st.chunk_done = None;
            }
            "resume" => {
                st.resumes += 1;
                if st.resumes > st.preempts {
                    out.violations.push(format!("id {id}: resume without matching preempt"));
                }
            }
            _ => {}
        }
        if let Some(t) = terminal_of(name) {
            st.terminal = Some(t);
        }
    }
    out.admitted = ids.len() as u64;
    // Sweep terminals in sorted id order: the per-id violation messages
    // land in the report deterministically (HashMap order would not).
    let mut by_id: Vec<(&u64, &IdState)> = ids.iter().collect();
    by_id.sort_by_key(|(id, _)| **id);
    for (id, st) in by_id {
        match st.terminal {
            Some("finish") => out.finished += 1,
            Some("request_shed") => out.shed += 1,
            Some("request_rejected") => out.rejected += 1,
            _ => {
                out.in_flight += 1;
                out.violations.push(format!("id {id}: no terminal event"));
            }
        }
    }
    if out.admitted != out.finished + out.shed + out.rejected + out.in_flight {
        out.violations.push(format!(
            "conservation broken: admitted {} != finished {} + shed {} + rejected {} + in-flight {}",
            out.admitted, out.finished, out.shed, out.rejected, out.in_flight
        ));
    }
    out
}

/// Check a live recorder in memory.
pub fn check_recorder(rec: &FlightRecorder) -> TraceCheck {
    check_stream(
        rec.iter().map(|e| {
            let chunk = match e.kind {
                EventKind::PrefillChunk { done, total, .. } => {
                    Some((done as u64, total as u64))
                }
                _ => None,
            };
            (e.kind.name(), e.kind.request_id(), chunk)
        }),
        rec.dropped(),
    )
}

/// Check a serialized JSONL trace (meta line + events). Also verifies
/// the meta line is present and event `seq` is strictly increasing.
pub fn check_jsonl(src: &str) -> Result<TraceCheck> {
    let mut lines = src.lines().filter(|l| !l.trim().is_empty());
    let meta_line = lines.next().context("empty trace file")?;
    let meta = Json::parse(meta_line).map_err(|e| anyhow::anyhow!("bad meta line: {e}"))?;
    if meta.get("trace").and_then(|t| t.as_str()) != Some("loki-flight-recorder") {
        anyhow::bail!("not a flight-recorder trace (missing meta line)");
    }
    let dropped = meta.get("dropped").and_then(|d| d.as_f64()).unwrap_or(0.0) as u64;
    let mut items: Vec<(String, Option<u64>, Option<(u64, u64)>)> = Vec::new();
    let mut last_seq: Option<u64> = None;
    for (i, line) in lines.enumerate() {
        let v = Json::parse(line).map_err(|e| anyhow::anyhow!("line {}: {e}", i + 2))?;
        let name = v
            .get("ev")
            .and_then(|e| e.as_str())
            .with_context(|| format!("line {}: missing \"ev\"", i + 2))?
            .to_string();
        let seq = v
            .get("seq")
            .and_then(|s| s.as_f64())
            .with_context(|| format!("line {}: missing \"seq\"", i + 2))? as u64;
        if let Some(prev) = last_seq {
            if seq <= prev {
                anyhow::bail!("line {}: seq {} not after {}", i + 2, seq, prev);
            }
        }
        last_seq = Some(seq);
        let id = v.get("id").and_then(|x| x.as_f64()).map(|x| x as u64);
        let chunk = match (
            v.get("done").and_then(|x| x.as_f64()),
            v.get("total").and_then(|x| x.as_f64()),
        ) {
            (Some(d), Some(t)) if name == "prefill_chunk" => Some((d as u64, t as u64)),
            _ => None,
        };
        items.push((name, id, chunk));
    }
    Ok(check_stream(items, dropped))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::event::FinishCode;

    fn rec_with(evs: &[EventKind]) -> FlightRecorder {
        let mut r = FlightRecorder::with_capacity(64);
        for (i, k) in evs.iter().enumerate() {
            r.record(i as f64, i as u64, *k);
        }
        r
    }

    fn admit(id: u64) -> EventKind {
        EventKind::RequestAdmitted { id, class: 0, prompt_len: 4, max_new: 2 }
    }

    fn finish(id: u64) -> EventKind {
        EventKind::Finish { id, reason: FinishCode::MaxTokens, tokens: 2 }
    }

    #[test]
    fn clean_lifecycle_conserves() {
        let r = rec_with(&[
            admit(1),
            admit(2),
            EventKind::PrefillStart { id: 1, lane: 0, tokens: 4 },
            EventKind::PrefillEnd { id: 1, lane: 0, tokens: 4 },
            EventKind::FirstToken { id: 1, ttft_steps: 1 },
            EventKind::PreemptPartial { id: 1, lane: 0, freed_blocks: 2, kept_len: 4 },
            EventKind::Resume { id: 1, lane: 0, recomputed_tokens: 0, kept_tokens: 4 },
            finish(1),
            EventKind::RequestShed { id: 2, class: 0, predicted_ttft_ms: 99.0 },
        ]);
        let chk = check_recorder(&r);
        assert!(chk.ok(), "{:?}", chk.violations);
        assert_eq!((chk.admitted, chk.finished, chk.shed, chk.rejected), (2, 1, 1, 0));
    }

    #[test]
    fn violations_are_caught() {
        // Event before admit.
        let chk = check_recorder(&rec_with(&[finish(5)]));
        assert!(chk.violations.iter().any(|v| v.contains("before request_admitted")));
        // Double terminal.
        let chk = check_recorder(&rec_with(&[admit(1), finish(1), finish(1)]));
        assert!(chk.violations.iter().any(|v| v.contains("after terminal")));
        // No terminal.
        let chk = check_recorder(&rec_with(&[admit(1)]));
        assert!(chk.violations.iter().any(|v| v.contains("no terminal")));
        assert_eq!(chk.in_flight, 1);
        // Resume without preempt.
        let chk = check_recorder(&rec_with(&[
            admit(1),
            EventKind::Resume { id: 1, lane: 0, recomputed_tokens: 1, kept_tokens: 0 },
            finish(1),
        ]));
        assert!(chk.violations.iter().any(|v| v.contains("resume without")));
        // Ring drops disqualify the trace.
        let mut r = FlightRecorder::with_capacity(1);
        r.record(0.0, 0, admit(1));
        r.record(1.0, 0, finish(1));
        assert!(!check_recorder(&r).ok());
    }

    #[test]
    fn chunked_prefill_lifecycle_conserves() {
        let r = rec_with(&[
            admit(1),
            EventKind::PrefillStart { id: 1, lane: 0, tokens: 10 },
            EventKind::PrefillChunk { id: 1, lane: 0, done: 4, total: 10 },
            EventKind::PrefillChunk { id: 1, lane: 0, done: 8, total: 10 },
            EventKind::PrefillChunk { id: 1, lane: 0, done: 10, total: 10 },
            EventKind::PrefillEnd { id: 1, lane: 0, tokens: 10 },
            EventKind::FirstToken { id: 1, ttft_steps: 3 },
            finish(1),
        ]);
        let chk = check_recorder(&r);
        assert!(chk.ok(), "{:?}", chk.violations);
        // And the JSONL path parses done/total into the same verdict.
        let from_text = check_jsonl(&trace_jsonl(&r)).unwrap();
        assert!(from_text.ok(), "{:?}", from_text.violations);
    }

    #[test]
    fn preempt_closes_the_prefill_episode_and_readmission_reopens_it() {
        let r = rec_with(&[
            admit(1),
            EventKind::PrefillStart { id: 1, lane: 0, tokens: 10 },
            EventKind::PrefillChunk { id: 1, lane: 0, done: 4, total: 10 },
            EventKind::PreemptFull { id: 1, lane: 0, freed_blocks: 2 },
            // Fresh episode restarts chunk progress from scratch.
            EventKind::PrefillStart { id: 1, lane: 1, tokens: 10 },
            EventKind::PrefillChunk { id: 1, lane: 1, done: 4, total: 10 },
            EventKind::PrefillChunk { id: 1, lane: 1, done: 10, total: 10 },
            EventKind::PrefillEnd { id: 1, lane: 1, tokens: 10 },
            EventKind::FirstToken { id: 1, ttft_steps: 9 },
            finish(1),
        ]);
        let chk = check_recorder(&r);
        // The preempt had no resume (the request was re-admitted as
        // fresh work), which is legal: resumes ≤ preempts.
        assert!(chk.ok(), "{:?}", chk.violations);
    }

    #[test]
    fn chunk_lifecycle_violations_are_caught() {
        // Chunk outside any episode.
        let chk = check_recorder(&rec_with(&[
            admit(1),
            EventKind::PrefillChunk { id: 1, lane: 0, done: 4, total: 10 },
            finish(1),
        ]));
        assert!(chk.violations.iter().any(|v| v.contains("outside a prefill episode")));
        // Non-increasing done.
        let chk = check_recorder(&rec_with(&[
            admit(1),
            EventKind::PrefillStart { id: 1, lane: 0, tokens: 10 },
            EventKind::PrefillChunk { id: 1, lane: 0, done: 4, total: 10 },
            EventKind::PrefillChunk { id: 1, lane: 0, done: 4, total: 10 },
            EventKind::PrefillEnd { id: 1, lane: 0, tokens: 10 },
            finish(1),
        ]));
        assert!(chk.violations.iter().any(|v| v.contains("not after")));
        // done past total.
        let chk = check_recorder(&rec_with(&[
            admit(1),
            EventKind::PrefillStart { id: 1, lane: 0, tokens: 10 },
            EventKind::PrefillChunk { id: 1, lane: 0, done: 11, total: 10 },
            EventKind::PrefillEnd { id: 1, lane: 0, tokens: 10 },
            finish(1),
        ]));
        assert!(chk.violations.iter().any(|v| v.contains("done 11 > total 10")));
        // Nested prefill_start and dangling prefill_end.
        let chk = check_recorder(&rec_with(&[
            admit(1),
            EventKind::PrefillStart { id: 1, lane: 0, tokens: 10 },
            EventKind::PrefillStart { id: 1, lane: 0, tokens: 10 },
            finish(1),
        ]));
        assert!(chk.violations.iter().any(|v| v.contains("inside an open prefill episode")));
        let chk = check_recorder(&rec_with(&[
            admit(1),
            EventKind::PrefillEnd { id: 1, lane: 0, tokens: 10 },
            finish(1),
        ]));
        assert!(chk.violations.iter().any(|v| v.contains("without prefill_start")));
    }

    #[test]
    fn jsonl_roundtrip_matches_in_memory_check() {
        let r = rec_with(&[admit(1), finish(1), admit(2), EventKind::RequestRejected { id: 2 }]);
        let text = trace_jsonl(&r);
        let from_text = check_jsonl(&text).unwrap();
        let from_mem = check_recorder(&r);
        assert!(from_text.ok() && from_mem.ok());
        assert_eq!(from_text.admitted, from_mem.admitted);
        assert_eq!(from_text.finished, from_mem.finished);
        assert_eq!(from_text.rejected, from_mem.rejected);
        // Serialization is deterministic: same recorder, same bytes.
        assert_eq!(trace_hash(text.as_bytes()), trace_hash(trace_jsonl(&r).as_bytes()));
    }

    #[test]
    fn cross_replica_disjointness_is_enforced() {
        let r0 = rec_with(&[admit(1), finish(1), admit(3), finish(3)]);
        let r1 = rec_with(&[admit(2), finish(2)]);
        let c0 = check_recorder(&r0);
        let c1 = check_recorder(&r1);
        assert_eq!(c0.admitted_ids.iter().copied().collect::<Vec<_>>(), vec![1, 3]);
        let labeled = vec![("replica0.jsonl".to_string(), c0), ("replica1.jsonl".to_string(), c1)];
        assert!(cross_replica_violations(&labeled).is_empty());
        // Same id admitted on both replicas: a routing leak.
        let leak = check_recorder(&rec_with(&[admit(3), finish(3)]));
        let labeled = vec![
            ("replica0.jsonl".to_string(), check_recorder(&r0)),
            ("replica1.jsonl".to_string(), leak),
        ];
        let v = cross_replica_violations(&labeled);
        assert_eq!(v.len(), 1);
        assert!(v[0].contains("id 3") && v[0].contains("replica0.jsonl"), "{v:?}");
    }

    #[test]
    fn jsonl_rejects_garbage() {
        assert!(check_jsonl("").is_err());
        assert!(check_jsonl("{\"not\":\"a trace\"}\n").is_err());
    }

    #[test]
    fn chrome_trace_has_tracks() {
        let r = rec_with(&[
            admit(1),
            EventKind::PrefillStart { id: 1, lane: 0, tokens: 4 },
            EventKind::PrefillEnd { id: 1, lane: 0, tokens: 4 },
            EventKind::FirstToken { id: 1, ttft_steps: 1 },
            finish(1),
        ]);
        let j = chrome_trace(&r);
        let evs = j.req("traceEvents").as_arr().unwrap();
        // 2 process_name metas + lane span + request span + instant.
        assert!(evs.len() >= 5, "{}", j.to_string());
        let round = Json::parse(&j.to_string()).unwrap();
        assert!(round.get("traceEvents").is_some());
        assert!(evs.iter().any(|e| e.get("ph").and_then(|p| p.as_str()) == Some("X")));
    }

    #[test]
    fn chrome_sibling_path() {
        assert_eq!(
            chrome_sibling(Path::new("/tmp/e2e-trace.jsonl")),
            PathBuf::from("/tmp/e2e-trace.chrome.json")
        );
    }

    #[test]
    fn fnv_hash_is_stable() {
        assert_eq!(trace_hash(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(trace_hash(b"a"), 0xaf63_dc4c_8601_ec8c);
    }
}
