//! Indexed attention kernels with explicit data-movement accounting.
//!
//! The paper's §4.3 point: PyTorch-style indexing (`K[:, idx, :d]`)
//! materializes dense temporary copies of KV-cache subsets; Loki's Triton
//! kernels index the cache in registers instead. We reproduce both
//! disciplines on CPU:
//!
//! * `*_indexed` kernels read the cache **in place** — feature access is a
//!   prefix slice (Loki: PCA orders components) or an arbitrary column
//!   gather (SparQ), token access an index list; no temporaries.
//! * `*_dense_copy` kernels first materialize the selected sub-matrix,
//!   then run a dense matmul — the HuggingFace/PyTorch baseline.
//!
//! Every kernel returns a [`DataMovement`] tally so the Eq.-5 bandwidth
//! model can be validated against what the implementation actually moved
//! (`repro-experiments table1`).

use super::AttnShape;
use crate::kvpool::{BlockId, PagedArena};
use crate::linalg::softmax::{softmax_inplace, NEG_INF};

/// Which feature (head-dim) subset a score kernel reads.
#[derive(Clone, Debug, PartialEq)]
pub enum FeatureAccess {
    /// All D components (vanilla / exact top-k scoring).
    Full,
    /// Leading `d` components — contiguous, Loki's PCA-ordered slice.
    Prefix(usize),
    /// Arbitrary component indices — SparQ's high-magnitude dims (strided
    /// gather; same arithmetic as Prefix(len) but worse locality).
    Gather(Vec<u16>),
}

impl FeatureAccess {
    pub fn count(&self, full: usize) -> usize {
        match self {
            FeatureAccess::Full => full,
            FeatureAccess::Prefix(d) => *d,
            FeatureAccess::Gather(ix) => ix.len(),
        }
    }
}

/// Bytes moved by one kernel invocation (analytic tally, not hardware
/// counters — on CPU the interesting quantity is "what a faithful GPU
/// implementation would have to fetch from DRAM").
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DataMovement {
    /// Bytes of KV-cache actually dereferenced.
    pub cache_bytes_read: u64,
    /// Bytes of dense temporaries materialized (0 for indexed kernels).
    pub temp_bytes: u64,
    /// Output bytes written.
    pub out_bytes: u64,
}

impl DataMovement {
    pub fn total(&self) -> u64 {
        self.cache_bytes_read + 2 * self.temp_bytes + self.out_bytes
    }

    pub fn add(&mut self, o: DataMovement) {
        self.cache_bytes_read += o.cache_bytes_read;
        self.temp_bytes += o.temp_bytes;
        self.out_bytes += o.out_bytes;
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Par {
    Serial,
    /// Threads split lanes only (SparQ-style m-parallelism).
    Lanes1D,
    /// Threads split (lane × sequence-block) tiles (Loki-style).
    Tiles2D,
}

fn n_threads(requested: Option<usize>) -> usize {
    requested
        .or_else(|| std::env::var("LOKI_THREADS").ok().and_then(|v| v.parse().ok()))
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4))
}

#[inline]
fn dot_prefix(a: &[f32], b: &[f32], d: usize) -> f32 {
    let mut s = 0.0;
    for i in 0..d {
        s += a[i] * b[i];
    }
    s
}

#[inline]
fn dot_gather(a: &[f32], b: &[f32], idx: &[u16]) -> f32 {
    let mut s = 0.0;
    for &i in idx {
        s += a[i as usize] * b[i as usize];
    }
    s
}

/// Per-(lane, seq-range) inner loop shared by all score kernels.
fn score_range(
    q: &[f32],
    kc_lane: &[f32],
    d_full: usize,
    feat: &FeatureAccess,
    scale: f32,
    j0: usize,
    j1: usize,
    out: &mut [f32],
) {
    match feat {
        FeatureAccess::Full => {
            for j in j0..j1 {
                out[j - j0] = dot_prefix(q, &kc_lane[j * d_full..], d_full) * scale;
            }
        }
        FeatureAccess::Prefix(d) => {
            for j in j0..j1 {
                out[j - j0] = dot_prefix(q, &kc_lane[j * d_full..], *d) * scale;
            }
        }
        FeatureAccess::Gather(idx) => {
            for j in j0..j1 {
                out[j - j0] = dot_gather(q, &kc_lane[j * d_full..(j + 1) * d_full], idx) * scale;
            }
        }
    }
}

/// Approximate/exact scores over the live cache, **no temporaries**.
///
/// q: `[lanes, D]`; kc: `[lanes, cap, D]` with `lane_stride = cap·D`;
/// out: `[lanes, live]`. Returns the bytes a faithful implementation
/// streams: `lanes · live · d_used · 4`.
#[allow(clippy::too_many_arguments)]
pub fn scores_indexed(
    shape: AttnShape,
    q: &[f32],
    kc: &[f32],
    lane_stride: usize,
    live: usize,
    feat: &FeatureAccess,
    scale: f32,
    par: Par,
    threads: Option<usize>,
    out: &mut [f32],
) -> DataMovement {
    let (lanes, d) = (shape.lanes, shape.head_dim);
    assert_eq!(q.len(), lanes * d);
    assert!(out.len() >= lanes * live);
    let mv = DataMovement {
        cache_bytes_read: (lanes * live * feat.count(d) * 4) as u64,
        temp_bytes: 0,
        out_bytes: (lanes * live * 4) as u64,
    };
    let t = n_threads(threads);
    match par {
        Par::Serial => {
            for lane in 0..lanes {
                score_range(
                    &q[lane * d..(lane + 1) * d],
                    &kc[lane * lane_stride..],
                    d,
                    feat,
                    scale,
                    0,
                    live,
                    &mut out[lane * live..(lane + 1) * live],
                );
            }
        }
        Par::Lanes1D => {
            // SparQ-style: one thread per chunk of lanes. With lanes < t
            // the surplus threads idle.
            let t = t.min(lanes.max(1));
            let lanes_per = lanes.div_ceil(t);
            std::thread::scope(|scope| {
                let mut rest = &mut out[..lanes * live];
                let mut lane0 = 0;
                while lane0 < lanes {
                    let n = lanes_per.min(lanes - lane0);
                    let (chunk, tail) = rest.split_at_mut(n * live);
                    rest = tail;
                    let l0 = lane0;
                    scope.spawn(move || {
                        for (li, lane) in (l0..l0 + n).enumerate() {
                            score_range(
                                &q[lane * d..(lane + 1) * d],
                                &kc[lane * lane_stride..],
                                d,
                                feat,
                                scale,
                                0,
                                live,
                                &mut chunk[li * live..(li + 1) * live],
                            );
                        }
                    });
                    lane0 += n;
                }
            });
        }
        Par::Tiles2D => {
            // Loki-style: tiles over (lane, seq block); sequence feeds all
            // cores even at lanes = 1.
            let want = t * 4;
            let blocks = want.div_ceil(lanes.max(1)).max(1).min(live.max(1));
            let bw = live.div_ceil(blocks).max(1);
            struct SendPtr(usize);
            let out_addr = SendPtr(out.as_mut_ptr() as usize);
            let out_addr = &out_addr;
            let total = lanes * blocks;
            let next = std::sync::atomic::AtomicUsize::new(0);
            let next = &next;
            std::thread::scope(|scope| {
                for _ in 0..t.min(total) {
                    scope.spawn(move || loop {
                        let w = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                        if w >= total {
                            break;
                        }
                        let lane = w / blocks;
                        let b = w % blocks;
                        let j0 = b * bw;
                        let j1 = ((b + 1) * bw).min(live);
                        if j0 >= j1 {
                            continue;
                        }
                        // SAFETY: (lane, j0..j1) ranges are disjoint.
                        let dst = unsafe {
                            std::slice::from_raw_parts_mut(
                                (out_addr.0 as *mut f32).add(lane * live + j0),
                                j1 - j0,
                            )
                        };
                        score_range(
                            &q[lane * d..(lane + 1) * d],
                            &kc[lane * lane_stride..],
                            d,
                            feat,
                            scale,
                            j0,
                            j1,
                            dst,
                        );
                    });
                }
            });
        }
    }
    mv
}

/// PyTorch-baseline scoring: materialize the `[live, d_used]` sub-matrix
/// per lane (`K[:, :live, feat]` → contiguous temp), then dense matmul.
#[allow(clippy::too_many_arguments)]
pub fn scores_dense_copy(
    shape: AttnShape,
    q: &[f32],
    kc: &[f32],
    lane_stride: usize,
    live: usize,
    feat: &FeatureAccess,
    scale: f32,
    out: &mut [f32],
) -> DataMovement {
    let (lanes, d) = (shape.lanes, shape.head_dim);
    let du = feat.count(d);
    let mut temp = vec![0.0f32; live * du];
    let mv = DataMovement {
        cache_bytes_read: (lanes * live * du * 4) as u64,
        temp_bytes: (lanes * live * du * 4) as u64,
        out_bytes: (lanes * live * 4) as u64,
    };
    let mut qbuf = vec![0.0f32; du];
    for lane in 0..lanes {
        let lane_k = &kc[lane * lane_stride..];
        // Gather into dense temp (the copy PyTorch indexing would make).
        for j in 0..live {
            let row = &lane_k[j * d..(j + 1) * d];
            match feat {
                FeatureAccess::Full => temp[j * du..(j + 1) * du].copy_from_slice(&row[..du]),
                FeatureAccess::Prefix(p) => {
                    temp[j * du..(j + 1) * du].copy_from_slice(&row[..*p])
                }
                FeatureAccess::Gather(idx) => {
                    for (t, &fi) in idx.iter().enumerate() {
                        temp[j * du + t] = row[fi as usize];
                    }
                }
            }
        }
        // The query must be gathered with the same feature set.
        let qrow = &q[lane * d..(lane + 1) * d];
        match feat {
            FeatureAccess::Gather(idx) => {
                for (t, &fi) in idx.iter().enumerate() {
                    qbuf[t] = qrow[fi as usize];
                }
            }
            _ => qbuf.copy_from_slice(&qrow[..du]),
        }
        let orow = &mut out[lane * live..(lane + 1) * live];
        for j in 0..live {
            orow[j] = dot_prefix(&qbuf, &temp[j * du..], du) * scale;
        }
    }
    mv
}

/// Exact attention over an index-selected token subset, reading the cache
/// in place (Loki lines 7–9). Returns the context vectors `[lanes, D]`.
#[allow(clippy::too_many_arguments)]
pub fn attend_rows_indexed(
    shape: AttnShape,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    lane_stride: usize,
    selected: &[Vec<u32>],
    scale: f32,
    threads: Option<usize>,
    out: &mut [f32],
) -> DataMovement {
    let (lanes, d) = (shape.lanes, shape.head_dim);
    assert_eq!(selected.len(), lanes);
    assert_eq!(out.len(), lanes * d);
    let total_sel: usize = selected.iter().map(|s| s.len()).sum();
    let mv = DataMovement {
        cache_bytes_read: (2 * total_sel * d * 4) as u64, // K and V rows
        temp_bytes: 0,
        out_bytes: (lanes * d * 4) as u64,
    };
    let t = n_threads(threads).min(lanes.max(1));
    let lanes_per = lanes.div_ceil(t);
    std::thread::scope(|scope| {
        let mut rest = &mut out[..];
        let mut lane0 = 0;
        while lane0 < lanes {
            let n = lanes_per.min(lanes - lane0);
            let (chunk, tail) = rest.split_at_mut(n * d);
            rest = tail;
            let l0 = lane0;
            scope.spawn(move || {
                let mut scores: Vec<f32> = Vec::new();
                for (li, lane) in (l0..l0 + n).enumerate() {
                    let sel = &selected[lane];
                    let qlane = &q[lane * d..(lane + 1) * d];
                    let klane = &kc[lane * lane_stride..];
                    let vlane = &vc[lane * lane_stride..];
                    scores.clear();
                    scores.extend(sel.iter().map(|&j| {
                        dot_prefix(qlane, &klane[j as usize * d..], d) * scale
                    }));
                    softmax_inplace(&mut scores);
                    let orow = &mut chunk[li * d..(li + 1) * d];
                    orow.fill(0.0);
                    for (p, &j) in scores.iter().zip(sel.iter()) {
                        let vrow = &vlane[j as usize * d..(j as usize + 1) * d];
                        for (o, &v) in orow.iter_mut().zip(vrow) {
                            *o += p * v;
                        }
                    }
                }
            });
            lane0 += n;
        }
    });
    mv
}

/// PyTorch-baseline gather-attend: densify selected K and V rows first.
#[allow(clippy::too_many_arguments)]
pub fn attend_rows_dense_copy(
    shape: AttnShape,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    lane_stride: usize,
    selected: &[Vec<u32>],
    scale: f32,
    out: &mut [f32],
) -> DataMovement {
    let (lanes, d) = (shape.lanes, shape.head_dim);
    let total_sel: usize = selected.iter().map(|s| s.len()).sum();
    let mv = DataMovement {
        cache_bytes_read: (2 * total_sel * d * 4) as u64,
        temp_bytes: (2 * total_sel * d * 4) as u64,
        out_bytes: (lanes * d * 4) as u64,
    };
    let max_k = selected.iter().map(|s| s.len()).max().unwrap_or(0);
    let mut kbuf = vec![0.0f32; max_k * d];
    let mut vbuf = vec![0.0f32; max_k * d];
    let mut scores = vec![0.0f32; max_k];
    for lane in 0..lanes {
        let sel = &selected[lane];
        let klane = &kc[lane * lane_stride..];
        let vlane = &vc[lane * lane_stride..];
        for (t, &j) in sel.iter().enumerate() {
            kbuf[t * d..(t + 1) * d].copy_from_slice(&klane[j as usize * d..(j as usize + 1) * d]);
            vbuf[t * d..(t + 1) * d].copy_from_slice(&vlane[j as usize * d..(j as usize + 1) * d]);
        }
        let qlane = &q[lane * d..(lane + 1) * d];
        for t in 0..sel.len() {
            scores[t] = dot_prefix(qlane, &kbuf[t * d..], d) * scale;
        }
        softmax_inplace(&mut scores[..sel.len()]);
        let orow = &mut out[lane * d..(lane + 1) * d];
        orow.fill(0.0);
        for t in 0..sel.len() {
            let p = scores[t];
            for (o, &v) in orow.iter_mut().zip(&vbuf[t * d..(t + 1) * d]) {
                *o += p * v;
            }
        }
    }
    mv
}

/// Full attention over the live prefix (the vanilla baseline): exact
/// scores + softmax + AV in place.
#[allow(clippy::too_many_arguments)]
pub fn full_attend(
    shape: AttnShape,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    lane_stride: usize,
    live: usize,
    scale: f32,
    threads: Option<usize>,
    out: &mut [f32],
) -> DataMovement {
    let all: Vec<Vec<u32>> = (0..shape.lanes).map(|_| (0..live as u32).collect()).collect();
    let mut scores_mv = DataMovement {
        cache_bytes_read: 0,
        temp_bytes: 0,
        out_bytes: 0,
    };
    let mv = attend_rows_indexed(shape, q, kc, vc, lane_stride, &all, scale, threads, out);
    scores_mv.add(mv);
    scores_mv
}

/// Approximate/exact scores for **one sequence** whose KV lives in a
/// paged arena behind a block table (the kvpool hot or cold tier),
/// reading the pool in place — the paged sibling of [`scores_indexed`].
///
/// Bit-identical to the flat kernel: the per-row dot product runs the
/// same operations in the same order over the same values, only the row
/// *address* goes through the block table. `feat` is interpreted against
/// `arena.width` (e.g. `Prefix(d_sub)` over a `d_hot`-wide hot tier).
pub fn scores_paged_lane(
    q: &[f32],
    arena: &PagedArena<'_>,
    table: &[BlockId],
    live: usize,
    feat: &FeatureAccess,
    scale: f32,
    out: &mut [f32],
) -> DataMovement {
    let du = feat.count(arena.width);
    assert!(du <= arena.width, "feature subset wider than arena rows");
    assert!(out.len() >= live);
    match feat {
        FeatureAccess::Full => {
            for j in 0..live {
                out[j] = dot_prefix(q, arena.row(table, j), arena.width) * scale;
            }
        }
        FeatureAccess::Prefix(d) => {
            for j in 0..live {
                out[j] = dot_prefix(q, arena.row(table, j), *d) * scale;
            }
        }
        FeatureAccess::Gather(idx) => {
            for j in 0..live {
                out[j] = dot_gather(q, arena.row(table, j), idx) * scale;
            }
        }
    }
    DataMovement {
        cache_bytes_read: (live * du * 4) as u64,
        temp_bytes: 0,
        out_bytes: (live * 4) as u64,
    }
}

/// Exact attention over an index-selected token subset of **one paged
/// sequence**, gathering K/V rows through the block table — the paged
/// sibling of [`attend_rows_indexed`] (whose per-lane math this mirrors
/// operation for operation, so outputs are bit-identical).
pub fn attend_rows_paged_lane(
    q: &[f32],
    k_arena: &PagedArena<'_>,
    v_arena: &PagedArena<'_>,
    table: &[BlockId],
    selected: &[u32],
    scale: f32,
    out: &mut [f32],
) -> DataMovement {
    let d = k_arena.width;
    assert_eq!(v_arena.width, d, "K and V arenas must agree on width");
    assert_eq!(out.len(), d);
    let mut scores: Vec<f32> = selected
        .iter()
        .map(|&j| dot_prefix(q, k_arena.row(table, j as usize), d) * scale)
        .collect();
    softmax_inplace(&mut scores);
    out.fill(0.0);
    for (p, &j) in scores.iter().zip(selected.iter()) {
        let vrow = v_arena.row(table, j as usize);
        for (o, &v) in out.iter_mut().zip(vrow) {
            *o += p * v;
        }
    }
    DataMovement {
        cache_bytes_read: (2 * selected.len() * d * 4) as u64,
        temp_bytes: 0,
        out_bytes: (d * 4) as u64,
    }
}

/// Mask helper: NEG_INF beyond `live` (used by variant code paths that
/// score the padded cache region).
pub fn mask_dead_slots(scores: &mut [f32], live: usize) {
    for s in scores[live..].iter_mut() {
        *s = NEG_INF;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn setup(
        lanes: usize,
        m: usize,
        d: usize,
        live: usize,
    ) -> (AttnShape, Vec<f32>, Vec<f32>, Vec<f32>) {
        let shape = AttnShape { lanes, head_dim: d, max_len: m };
        let mut rng = Xoshiro256::new(42);
        let q = rng.normal_vec(lanes * d);
        let kc = rng.normal_vec(lanes * m * d);
        let vc = rng.normal_vec(lanes * m * d);
        let _ = live;
        (shape, q, kc, vc)
    }

    #[test]
    fn score_kernels_agree() {
        let (shape, q, kc, _vc) = setup(3, 64, 16, 50);
        let live = 50;
        let stride = 64 * 16;
        let scale = 0.25;
        for feat in [
            FeatureAccess::Full,
            FeatureAccess::Prefix(4),
            FeatureAccess::Gather(vec![0, 3, 7, 11]),
        ] {
            let mut a = vec![0.0; 3 * live];
            let mut b = vec![0.0; 3 * live];
            let mut c = vec![0.0; 3 * live];
            let mut dcp = vec![0.0; 3 * live];
            scores_indexed(
                shape,
                &q,
                &kc,
                stride,
                live,
                &feat,
                scale,
                Par::Serial,
                Some(1),
                &mut a,
            );
            scores_indexed(
                shape,
                &q,
                &kc,
                stride,
                live,
                &feat,
                scale,
                Par::Lanes1D,
                Some(4),
                &mut b,
            );
            scores_indexed(
                shape,
                &q,
                &kc,
                stride,
                live,
                &feat,
                scale,
                Par::Tiles2D,
                Some(4),
                &mut c,
            );
            scores_dense_copy(shape, &q, &kc, stride, live, &feat, scale, &mut dcp);
            for i in 0..3 * live {
                assert!((a[i] - b[i]).abs() < 1e-5, "{feat:?} 1d");
                assert!((a[i] - c[i]).abs() < 1e-5, "{feat:?} 2d");
                // Gather through dense copy differs only by float order.
                assert!((a[i] - dcp[i]).abs() < 1e-4, "{feat:?} dense");
            }
        }
    }

    #[test]
    fn prefix_equals_gather_of_leading_dims() {
        let (shape, q, kc, _) = setup(2, 32, 8, 20);
        let stride = 32 * 8;
        let mut a = vec![0.0; 2 * 20];
        let mut b = vec![0.0; 2 * 20];
        let prefix = FeatureAccess::Prefix(3);
        scores_indexed(shape, &q, &kc, stride, 20, &prefix, 1.0, Par::Serial, Some(1), &mut a);
        let gather = FeatureAccess::Gather(vec![0, 1, 2]);
        scores_indexed(shape, &q, &kc, stride, 20, &gather, 1.0, Par::Serial, Some(1), &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn attend_kernels_agree_and_account_bytes() {
        let (shape, q, kc, vc) = setup(4, 64, 16, 60);
        let stride = 64 * 16;
        let sel: Vec<Vec<u32>> =
            (0..4).map(|l| (0..15u32).map(|x| x * 4 + l as u32 % 4).collect()).collect();
        let mut a = vec![0.0; 4 * 16];
        let mut b = vec![0.0; 4 * 16];
        let mva = attend_rows_indexed(shape, &q, &kc, &vc, stride, &sel, 0.25, Some(3), &mut a);
        let mvb = attend_rows_dense_copy(shape, &q, &kc, &vc, stride, &sel, 0.25, &mut b);
        for i in 0..a.len() {
            assert!((a[i] - b[i]).abs() < 1e-4);
        }
        assert_eq!(mva.temp_bytes, 0);
        assert_eq!(mvb.temp_bytes, (2 * 4 * 15 * 16 * 4) as u64);
        assert_eq!(mva.cache_bytes_read, mvb.cache_bytes_read);
    }

    #[test]
    fn full_attend_matches_selected_all() {
        let (shape, q, kc, vc) = setup(2, 32, 8, 32);
        let stride = 32 * 8;
        let mut a = vec![0.0; 2 * 8];
        let mut b = vec![0.0; 2 * 8];
        full_attend(shape, &q, &kc, &vc, stride, 32, 0.3, Some(2), &mut a);
        let all: Vec<Vec<u32>> = (0..2).map(|_| (0..32).collect()).collect();
        attend_rows_indexed(shape, &q, &kc, &vc, stride, &all, 0.3, Some(1), &mut b);
        assert_eq!(a, b);
    }

    /// Copy one flat lane into a paged arena under a *permuted* block
    /// table (so the indirection is actually exercised) and check the
    /// paged kernels agree with the flat ones bit for bit.
    #[test]
    fn paged_kernels_match_flat_with_permuted_blocks() {
        let (shape, q, kc, vc) = setup(1, 64, 16, 64);
        let (d, live, bs) = (16usize, 50usize, 8usize);
        let stride = 64 * d;
        let nblocks = 64 / bs;
        let table: Vec<BlockId> = vec![3, 7, 0, 5, 1, 2, 6, 4];
        assert_eq!(table.len(), nblocks);
        let mut k_arena_data = vec![0.0f32; nblocks * bs * d];
        let mut v_arena_data = vec![0.0f32; nblocks * bs * d];
        for j in 0..64 {
            let b = table[j / bs] as usize;
            let dst = (b * bs + j % bs) * d;
            k_arena_data[dst..dst + d].copy_from_slice(&kc[j * d..(j + 1) * d]);
            v_arena_data[dst..dst + d].copy_from_slice(&vc[j * d..(j + 1) * d]);
        }
        let k_arena = PagedArena { data: &k_arena_data, block_size: bs, width: d };
        let v_arena = PagedArena { data: &v_arena_data, block_size: bs, width: d };

        let feats =
            [FeatureAccess::Full, FeatureAccess::Prefix(5), FeatureAccess::Gather(vec![1, 4, 9])];
        for feat in feats {
            let mut flat = vec![0.0; live];
            let mut paged = vec![0.0; live];
            let mv_flat = scores_indexed(
                shape, &q, &kc, stride, live, &feat, 0.125, Par::Serial, Some(1), &mut flat,
            );
            let mv_paged =
                scores_paged_lane(&q[..d], &k_arena, &table, live, &feat, 0.125, &mut paged);
            assert_eq!(flat, paged, "{feat:?} scores must be bit-identical");
            assert_eq!(mv_flat.cache_bytes_read, mv_paged.cache_bytes_read);
        }

        let sel: Vec<u32> = (0..live as u32).step_by(3).collect();
        let mut flat_ctx = vec![0.0; d];
        let mut paged_ctx = vec![0.0; d];
        attend_rows_indexed(
            shape, &q, &kc, &vc, stride, &[sel.clone()], 0.25, Some(1), &mut flat_ctx,
        );
        attend_rows_paged_lane(&q[..d], &k_arena, &v_arena, &table, &sel, 0.25, &mut paged_ctx);
        assert_eq!(flat_ctx, paged_ctx, "paged attend must be bit-identical");
    }

    #[test]
    fn movement_scales_with_d_used() {
        let (shape, q, kc, _) = setup(1, 128, 32, 128);
        let stride = 128 * 32;
        let mut out = vec![0.0; 128];
        let full = scores_indexed(
            shape,
            &q,
            &kc,
            stride,
            128,
            &FeatureAccess::Full,
            1.0,
            Par::Serial,
            Some(1),
            &mut out,
        );
        let quarter = scores_indexed(
            shape,
            &q,
            &kc,
            stride,
            128,
            &FeatureAccess::Prefix(8),
            1.0,
            Par::Serial,
            Some(1),
            &mut out,
        );
        assert_eq!(full.cache_bytes_read, 4 * quarter.cache_bytes_read);
    }
}
