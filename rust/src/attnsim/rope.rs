//! Rotary position embeddings (rotate-half form), matching
//! `python/compile/model.py::apply_rope` exactly — pre-/post-rotary key
//! analysis in Rust must agree with the python-calibrated bases.

/// Apply RoPE in place to a `[head_dim]` vector at `position`.
pub fn apply_rope(x: &mut [f32], position: usize, theta_base: f32) {
    let d = x.len();
    let half = d / 2;
    debug_assert_eq!(half * 2, d, "head_dim must be even");
    for i in 0..half {
        let freq = theta_base.powf(-(i as f32) / half as f32);
        let ang = position as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let x1 = x[i];
        let x2 = x[i + half];
        x[i] = x1 * cos - x2 * sin;
        x[i + half] = x1 * sin + x2 * cos;
    }
}

/// Apply RoPE to every `[head_dim]` row of a `[n, head_dim]` block where
/// row `i` sits at sequence position `start + i`.
pub fn apply_rope_rows(rows: &mut [f32], head_dim: usize, start: usize, theta_base: f32) {
    for (i, row) in rows.chunks_exact_mut(head_dim).enumerate() {
        apply_rope(row, start + i, theta_base);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn position_zero_is_identity() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0];
        let orig = x.clone();
        apply_rope(&mut x, 0, 10000.0);
        assert_eq!(x, orig);
    }

    #[test]
    fn preserves_norm() {
        let mut x = vec![0.3, -1.2, 0.7, 2.0, -0.5, 0.1];
        let n0: f32 = x.iter().map(|v| v * v).sum();
        apply_rope(&mut x, 17, 10000.0);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() < 1e-5);
    }

    #[test]
    fn relative_rotation_property() {
        // RoPE makes dot(q_m, k_n) depend only on (m - n): check
        // dot(rope(q, 5), rope(k, 3)) == dot(rope(q, 7), rope(k, 5)).
        let q0 = vec![0.5, -0.25, 1.5, 0.75];
        let k0 = vec![-1.0, 0.4, 0.2, 0.9];
        let dot = |a: &[f32], b: &[f32]| -> f32 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
        let mut q1 = q0.clone();
        let mut k1 = k0.clone();
        apply_rope(&mut q1, 5, 10000.0);
        apply_rope(&mut k1, 3, 10000.0);
        let mut q2 = q0.clone();
        let mut k2 = k0.clone();
        apply_rope(&mut q2, 7, 10000.0);
        apply_rope(&mut k2, 5, 10000.0);
        assert!((dot(&q1, &k1) - dot(&q2, &k2)).abs() < 1e-4);
    }

    #[test]
    fn rows_use_consecutive_positions() {
        let mut block = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0];
        apply_rope_rows(&mut block, 4, 3, 10000.0);
        let mut row0 = vec![1.0, 0.0, 0.0, 0.0];
        let mut row1 = vec![1.0, 0.0, 0.0, 0.0];
        apply_rope(&mut row0, 3, 10000.0);
        apply_rope(&mut row1, 4, 10000.0);
        assert_eq!(&block[..4], &row0[..]);
        assert_eq!(&block[4..], &row1[..]);
    }
}
