//! Decode-step attention variants over the substrate cache.
//!
//! Each variant performs one generation step for a single (layer, lanes)
//! problem and reports (a) the context vectors, (b) which cache slots it
//! attended to (for the Fig-6 Jaccard agreement study) and (c) the data
//! movement tally. Variants mirror the paper's comparison set:
//!
//! | variant        | ranking signal                  | final attention |
//! |----------------|---------------------------------|-----------------|
//! | Full           | —                               | all slots       |
//! | ExactTopK      | exact scores (full D)           | top-k           |
//! | Loki           | approx scores (leading d comps) | top-k, full D   |
//! | SparQ          | approx scores (|q|-top d comps) | top-k, full D   |
//! | H2O            | accumulated attention mass      | hh ∪ recent     |
//! | StreamingLLM   | position (sinks + window)       | sinks ∪ window  |
//! | PCAAttn        | —                               | approx scores   |
//!
//! Loki/SparQ assume the cache already holds *rotated* keys K̂ = K·P
//! (rotation happens at append time in the serving path — Lemma 4.1 makes
//! exact attention in rotated space exact).

use super::kernels::{
    attend_rows_indexed, attend_rows_paged_lane, scores_indexed, scores_paged_lane, DataMovement,
    FeatureAccess, Par,
};
use super::AttnShape;
use crate::kvpool::{PoolSeqId, TieredKvPool};
use crate::linalg::softmax::softmax_masked_inplace;
use crate::linalg::topk::{top_k_indices, TopKAlgo};

#[derive(Clone, Debug, PartialEq)]
pub enum AttnVariant {
    Full,
    ExactTopK,
    Loki,
    SparQ,
    H2O,
    StreamingLlm,
    PcaAttn,
}

/// Knobs for a decode step (k/d given as absolute counts; callers convert
/// the paper's k_f·S / d_f·D fractions).
#[derive(Clone, Debug)]
pub struct VariantParams {
    /// Tokens selected for exact attention (top-k / H2O budget / window).
    pub k_sel: usize,
    /// Principal components used for approximate scoring (Loki/SparQ/PCAAttn).
    pub d_sub: usize,
    /// StreamingLLM attention sinks.
    pub sinks: usize,
    pub topk_algo: TopKAlgo,
    pub par: Par,
    pub threads: Option<usize>,
}

impl Default for VariantParams {
    fn default() -> Self {
        Self {
            k_sel: usize::MAX,
            d_sub: usize::MAX,
            sinks: 4,
            topk_algo: TopKAlgo::Heap,
            par: Par::Tiles2D,
            threads: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// `[lanes, head_dim]` context vectors.
    pub context: Vec<f32>,
    /// Selected slot indices per lane (what was attended to).
    pub selected: Vec<Vec<u32>>,
    pub movement: DataMovement,
}

/// Per-lane H2O accumulator state (attention mass per slot).
pub type H2oState = Vec<Vec<f32>>;

/// Run one decode step of `variant`.
///
/// * `q` — `[lanes, D]`, already rotated for Loki/SparQ/PCAAttn paths.
/// * `kc`/`vc` — caches with `lane_stride` floats between lanes.
/// * `live` — number of live slots.
/// * `h2o` — accumulator, updated in place when variant == H2O.
#[allow(clippy::too_many_arguments)]
pub fn decode_attend(
    variant: &AttnVariant,
    shape: AttnShape,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    lane_stride: usize,
    live: usize,
    params: &VariantParams,
    mut h2o: Option<&mut H2oState>,
) -> DecodeOutput {
    let lanes = shape.lanes;
    let d = shape.head_dim;
    let scale = 1.0 / (d as f32).sqrt();
    let k_sel = params.k_sel.min(live);
    let mut movement = DataMovement::default();
    let mut scores = vec![0.0f32; lanes * live];

    let selected: Vec<Vec<u32>> = match variant {
        AttnVariant::Full => (0..lanes).map(|_| (0..live as u32).collect()).collect(),
        AttnVariant::ExactTopK | AttnVariant::Loki | AttnVariant::SparQ => {
            let feat = match variant {
                AttnVariant::ExactTopK => FeatureAccess::Full,
                AttnVariant::Loki => FeatureAccess::Prefix(params.d_sub.min(d)),
                AttnVariant::SparQ => {
                    // SparQ ranks feature dims by |q| per lane; a single
                    // shared gather set keeps the kernel contract simple —
                    // use lane 0's top-|q| dims (the benchmarked effect is
                    // the strided gather, not the dim choice).
                    let du = params.d_sub.min(d);
                    let mags: Vec<f32> = (0..d).map(|i| q[i].abs()).collect();
                    let mut ix = top_k_indices(TopKAlgo::Sort, &mags, du);
                    ix.sort_unstable();
                    FeatureAccess::Gather(ix.iter().map(|&i| i as u16).collect())
                }
                _ => unreachable!(),
            };
            movement.add(scores_indexed(
                shape, q, kc, lane_stride, live, &feat, scale, params.par,
                params.threads, &mut scores,
            ));
            (0..lanes)
                .map(|lane| {
                    top_k_indices(params.topk_algo, &scores[lane * live..(lane + 1) * live], k_sel)
                })
                .collect()
        }
        AttnVariant::H2O => {
            let state = h2o.as_deref_mut().expect("H2O needs accumulator state");
            assert_eq!(state.len(), lanes);
            let recent_w = k_sel - k_sel / 2;
            let hh_n = k_sel / 2;
            let recent_start = live.saturating_sub(recent_w);
            (0..lanes)
                .map(|lane| {
                    let acc = &state[lane];
                    let mut sel: Vec<u32> = (recent_start as u32..live as u32).collect();
                    if hh_n > 0 && recent_start > 0 {
                        let hh = top_k_indices(params.topk_algo, &acc[..recent_start], hh_n);
                        sel.extend(hh);
                    }
                    sel.sort_unstable();
                    sel
                })
                .collect()
        }
        AttnVariant::StreamingLlm => {
            // Budget invariant: sinks + window ≤ k_sel. Sinks are capped
            // at k_sel − 1 so the window always keeps the newest token —
            // uncapped, `sinks ≥ k_sel` plus the forced 1-token window
            // would select k_sel + 1 slots and overrun the budget.
            let sinks = params.sinks.min(k_sel.saturating_sub(1));
            let window = k_sel.saturating_sub(sinks).max(1);
            let start = live.saturating_sub(window);
            (0..lanes)
                .map(|_| {
                    let mut sel: Vec<u32> = (0..sinks.min(start) as u32).collect();
                    sel.extend(start as u32..live as u32);
                    sel
                })
                .collect()
        }
        AttnVariant::PcaAttn => (0..lanes).map(|_| (0..live as u32).collect()).collect(),
    };

    // Final attention.
    let mut context = vec![0.0f32; lanes * d];
    match variant {
        AttnVariant::PcaAttn => {
            // Softmax directly over the d-dim approximate scores (App. E).
            let feat = FeatureAccess::Prefix(params.d_sub.min(d));
            movement.add(scores_indexed(
                shape, q, kc, lane_stride, live, &feat, scale, params.par,
                params.threads, &mut scores,
            ));
            let mask = vec![true; live];
            for lane in 0..lanes {
                let srow = &mut scores[lane * live..(lane + 1) * live];
                softmax_masked_inplace(srow, &mask);
                let vlane = &vc[lane * lane_stride..];
                let orow = &mut context[lane * d..(lane + 1) * d];
                for (j, &p) in srow.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    for (o, &v) in orow.iter_mut().zip(&vlane[j * d..(j + 1) * d]) {
                        *o += p * v;
                    }
                }
            }
            movement.cache_bytes_read += (lanes * live * d * 4) as u64; // V reads
        }
        _ => {
            movement.add(attend_rows_indexed(
                shape, q, kc, vc, lane_stride, &selected, scale, params.threads,
                &mut context,
            ));
        }
    }

    // H2O accumulator update: add this step's attention probabilities.
    if let AttnVariant::H2O = variant {
        let state = h2o.as_deref_mut().expect("checked above");
        for lane in 0..lanes {
            let sel = &selected[lane];
            let qlane = &q[lane * d..(lane + 1) * d];
            let klane = &kc[lane * lane_stride..];
            let mut probs: Vec<f32> = sel
                .iter()
                .map(|&j| {
                    let mut s = 0.0;
                    for i in 0..d {
                        s += qlane[i] * klane[j as usize * d + i];
                    }
                    s * scale
                })
                .collect();
            let mask = vec![true; probs.len()];
            softmax_masked_inplace(&mut probs, &mask);
            let acc = &mut state[lane];
            if acc.len() < live {
                acc.resize(live, 0.0);
            }
            for (&j, &p) in sel.iter().zip(&probs) {
                acc[j as usize] += p;
            }
        }
    }

    DecodeOutput { context, selected, movement }
}

/// Run one decode step of `variant` over **paged** KV state: one pool
/// sequence per lane, scores ranked in the always-hot low-rank tier
/// (Loki/PCAAttn) or the cold full-D tier (exact/SparQ), then full-D rows
/// gathered through the block table for only the selected slots.
///
/// Guarantees bit-identical context vectors to [`decode_attend`] over a
/// flat `InPlace` cache holding the same rows (the paged kernels run the
/// same float operations in the same order; see
/// `tests/kvpool_properties.rs`). Unlike the flat path, lanes may be
/// ragged — each sequence attends over its own live length.
///
/// Residency side effects: hot-tier passes and cold-page gathers are
/// tallied in `pool.tier_stats` (fault/demotion modeling lives in the
/// pool, data movement in the returned [`DataMovement`]).
pub fn decode_attend_paged(
    variant: &AttnVariant,
    pool: &mut TieredKvPool,
    seqs: &[PoolSeqId],
    q: &[f32],
    params: &VariantParams,
    mut h2o: Option<&mut H2oState>,
) -> DecodeOutput {
    let lanes = seqs.len();
    let d = pool.head_dim();
    assert_eq!(q.len(), lanes * d, "q must be [lanes, head_dim]");
    let scale = 1.0 / (d as f32).sqrt();
    let mut movement = DataMovement::default();
    let mut context = vec![0.0f32; lanes * d];
    let mut selected: Vec<Vec<u32>> = Vec::with_capacity(lanes);

    // SparQ's shared gather set — lane 0's top-|q| components, the same
    // convention as the flat path — computed once, not per lane.
    // Scattered dims cannot live in the PCA-prefix hot tier, so SparQ
    // ranks against cold full-D pages.
    let sparq_feat = matches!(variant, AttnVariant::SparQ).then(|| {
        let du = params.d_sub.min(d);
        let mags: Vec<f32> = (0..d).map(|i| q[i].abs()).collect();
        let mut ix = top_k_indices(TopKAlgo::Sort, &mags, du);
        ix.sort_unstable();
        FeatureAccess::Gather(ix.iter().map(|&i| i as u16).collect())
    });

    for (lane, &seq) in seqs.iter().enumerate() {
        let live = pool.len(seq);
        let k_sel = params.k_sel.min(live);
        let qlane = &q[lane * d..(lane + 1) * d];

        let sel: Vec<u32> = match variant {
            AttnVariant::Full | AttnVariant::PcaAttn => (0..live as u32).collect(),
            AttnVariant::ExactTopK | AttnVariant::Loki | AttnVariant::SparQ => {
                let mut scores = vec![0.0f32; live];
                // Loki clamps d_sub to head_dim exactly like the flat
                // path; when the clamped width still exceeds the hot
                // tier it ranks against the cold full-D rotated keys (a
                // prefix of the same rows), so flat and paged can never
                // disagree on the effective d_sub — the paged path just
                // loses the hot-tier locality win and accounts the pass
                // as a cold gather.
                let hot_rank = matches!(variant, AttnVariant::Loki)
                    && params.d_sub.min(d) <= pool.d_hot();
                {
                    let feat_local;
                    let (arena, feat) = match variant {
                        AttnVariant::ExactTopK => {
                            feat_local = FeatureAccess::Full;
                            (pool.cold_k_view(), &feat_local)
                        }
                        AttnVariant::Loki => {
                            let d_sub = params.d_sub.min(d);
                            feat_local = FeatureAccess::Prefix(d_sub);
                            if d_sub <= pool.d_hot() {
                                (pool.hot_view(), &feat_local)
                            } else {
                                (pool.cold_k_view(), &feat_local)
                            }
                        }
                        AttnVariant::SparQ => {
                            (pool.cold_k_view(), sparq_feat.as_ref().expect("precomputed"))
                        }
                        _ => unreachable!(),
                    };
                    let table = pool.blocks(seq);
                    movement.add(scores_paged_lane(
                        qlane, &arena, table, live, feat, scale, &mut scores,
                    ));
                }
                if hot_rank {
                    pool.account_hot_pass();
                } else {
                    // Cold-tier ranking genuinely touches every page.
                    let all: Vec<u32> = (0..live as u32).collect();
                    pool.account_gather(seq, &all);
                }
                top_k_indices(params.topk_algo, &scores, k_sel)
            }
            AttnVariant::H2O => {
                let state = h2o.as_deref_mut().expect("H2O needs accumulator state");
                let acc = &state[lane];
                let recent_w = k_sel - k_sel / 2;
                let hh_n = k_sel / 2;
                let recent_start = live.saturating_sub(recent_w);
                let mut sel: Vec<u32> = (recent_start as u32..live as u32).collect();
                if hh_n > 0 && recent_start > 0 {
                    let hh = top_k_indices(params.topk_algo, &acc[..recent_start], hh_n);
                    sel.extend(hh);
                }
                sel.sort_unstable();
                sel
            }
            AttnVariant::StreamingLlm => {
                // Same budget cap as the flat path: sinks + window ≤ k_sel
                // with the newest token always in the window.
                let sinks = params.sinks.min(k_sel.saturating_sub(1));
                let window = k_sel.saturating_sub(sinks).max(1);
                let start = live.saturating_sub(window);
                let mut sel: Vec<u32> = (0..sinks.min(start) as u32).collect();
                sel.extend(start as u32..live as u32);
                sel
            }
        };

        // Final attention: gather full-D pages for the selected slots only.
        pool.account_gather(seq, &sel);
        match variant {
            AttnVariant::PcaAttn => {
                // Same clamp/fallback contract as the Loki ranking pass:
                // clamp to head_dim, serve from the hot tier when it is
                // wide enough, fall back to the cold full-D keys (bit-
                // identical prefix) otherwise.
                let d_sub = params.d_sub.min(d);
                let from_hot = d_sub <= pool.d_hot();
                let mut scores = vec![0.0f32; live];
                {
                    let arena = if from_hot { pool.hot_view() } else { pool.cold_k_view() };
                    let table = pool.blocks(seq);
                    movement.add(scores_paged_lane(
                        qlane,
                        &arena,
                        table,
                        live,
                        &FeatureAccess::Prefix(d_sub),
                        scale,
                        &mut scores,
                    ));
                }
                if from_hot {
                    pool.account_hot_pass();
                } else {
                    let all: Vec<u32> = (0..live as u32).collect();
                    pool.account_gather(seq, &all);
                }
                let mask = vec![true; live];
                softmax_masked_inplace(&mut scores, &mask);
                let varena = pool.cold_v_view();
                let table = pool.blocks(seq);
                let orow = &mut context[lane * d..(lane + 1) * d];
                for (j, &p) in scores.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    for (o, &v) in orow.iter_mut().zip(varena.row(table, j)) {
                        *o += p * v;
                    }
                }
                movement.cache_bytes_read += (live * d * 4) as u64; // V reads
            }
            _ => {
                let karena = pool.cold_k_view();
                let varena = pool.cold_v_view();
                let table = pool.blocks(seq);
                movement.add(attend_rows_paged_lane(
                    qlane,
                    &karena,
                    &varena,
                    table,
                    &sel,
                    scale,
                    &mut context[lane * d..(lane + 1) * d],
                ));
            }
        }

        // H2O accumulator update, same math as the flat path but through
        // the cold key arena.
        if let AttnVariant::H2O = variant {
            let mut probs: Vec<f32> = {
                let karena = pool.cold_k_view();
                let table = pool.blocks(seq);
                sel.iter()
                    .map(|&j| {
                        let krow = karena.row(table, j as usize);
                        let mut s = 0.0;
                        for i in 0..d {
                            s += qlane[i] * krow[i];
                        }
                        s * scale
                    })
                    .collect()
            };
            let mask = vec![true; probs.len()];
            softmax_masked_inplace(&mut probs, &mask);
            let state = h2o.as_deref_mut().expect("checked above");
            let acc = &mut state[lane];
            if acc.len() < live {
                acc.resize(live, 0.0);
            }
            for (&j, &p) in sel.iter().zip(&probs) {
                acc[j as usize] += p;
            }
        }

        selected.push(sel);
    }

    DecodeOutput { context, selected, movement }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn setup(lanes: usize, m: usize, d: usize) -> (AttnShape, Vec<f32>, Vec<f32>, Vec<f32>) {
        let shape = AttnShape { lanes, head_dim: d, max_len: m };
        let mut rng = Xoshiro256::new(7);
        let q = rng.normal_vec(lanes * d);
        let k = rng.normal_vec(lanes * m * d);
        let v = rng.normal_vec(lanes * m * d);
        (shape.clone(), q, k, v)
    }

    #[test]
    fn exact_topk_with_k_eq_live_matches_full() {
        let (shape, q, kc, vc) = setup(2, 32, 8);
        let stride = 32 * 8;
        let p_full = VariantParams::default();
        let p_topk = VariantParams { k_sel: 32, ..Default::default() };
        let a = decode_attend(&AttnVariant::Full, shape, &q, &kc, &vc, stride, 32, &p_full, None);
        let b =
            decode_attend(&AttnVariant::ExactTopK, shape, &q, &kc, &vc, stride, 32, &p_topk, None);
        for (x, y) in a.context.iter().zip(&b.context) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn loki_with_full_d_matches_exact_topk_selection() {
        let (shape, q, kc, vc) = setup(3, 64, 16);
        let stride = 64 * 16;
        let p = VariantParams { k_sel: 16, d_sub: 16, ..Default::default() };
        let a = decode_attend(&AttnVariant::ExactTopK, shape, &q, &kc, &vc, stride, 64, &p, None);
        let b = decode_attend(&AttnVariant::Loki, shape, &q, &kc, &vc, stride, 64, &p, None);
        for lane in 0..3 {
            let mut sa = a.selected[lane].clone();
            let mut sb = b.selected[lane].clone();
            sa.sort_unstable();
            sb.sort_unstable();
            assert_eq!(sa, sb);
        }
    }

    #[test]
    fn loki_moves_fewer_bytes_than_exact() {
        let (shape, q, kc, vc) = setup(2, 128, 32);
        let stride = 128 * 32;
        let exact = VariantParams { k_sel: 32, ..Default::default() };
        let loki = VariantParams { k_sel: 32, d_sub: 8, ..Default::default() };
        let a =
            decode_attend(&AttnVariant::ExactTopK, shape, &q, &kc, &vc, stride, 128, &exact, None);
        let b = decode_attend(&AttnVariant::Loki, shape, &q, &kc, &vc, stride, 128, &loki, None);
        assert!(b.movement.cache_bytes_read < a.movement.cache_bytes_read);
    }

    #[test]
    fn h2o_respects_budget_and_monotone_acc() {
        let (shape, q, kc, vc) = setup(2, 64, 8);
        let stride = 64 * 8;
        let mut state: H2oState = vec![vec![0.0; 64]; 2];
        // Give slot 3 a huge accumulated mass: must be kept as heavy hitter.
        state[0][3] = 100.0;
        let p = VariantParams { k_sel: 8, ..Default::default() };
        let out =
            decode_attend(&AttnVariant::H2O, shape, &q, &kc, &vc, stride, 64, &p, Some(&mut state));
        assert!(out.selected[0].contains(&3));
        assert_eq!(out.selected[0].len(), 8);
        // Recent window must include the newest slot.
        assert!(out.selected[0].contains(&63));
        // acc only grows.
        assert!(state[0][3] >= 100.0);
    }

    #[test]
    fn streaming_keeps_sinks_and_window() {
        let (shape, q, kc, vc) = setup(1, 64, 8);
        let stride = 64 * 8;
        let p = VariantParams { k_sel: 12, sinks: 4, ..Default::default() };
        let out =
            decode_attend(&AttnVariant::StreamingLlm, shape, &q, &kc, &vc, stride, 64, &p, None);
        let sel = &out.selected[0];
        for s in 0..4u32 {
            assert!(sel.contains(&s), "sink {s} missing");
        }
        assert!(sel.contains(&63));
        assert!(!sel.contains(&30), "middle token should be evicted");
    }

    /// Same rows, flat `[lanes, max_len, D]` layout vs paged pool with a
    /// shared-prefix-capable block table: every variant must produce the
    /// exact same bits (`==` on f32, no tolerance).
    #[test]
    fn paged_decode_matches_flat_bitwise() {
        use crate::kvpool::{TieredKvPool, TieredPoolCfg};
        let (shape, q, kc, vc) = setup(3, 64, 16);
        let (d, live, stride) = (16usize, 64usize, 64 * 16usize);
        let mut pool = TieredKvPool::new(TieredPoolCfg {
            num_blocks: 64,
            block_size: 8,
            head_dim: d,
            d_hot: 8,
            cold_resident_blocks: 0,
        });
        let seqs: Vec<_> = (0..3)
            .map(|lane| {
                let s = pool.new_seq();
                pool.load_prefix(
                    s,
                    &kc[lane * stride..lane * stride + live * d],
                    &vc[lane * stride..lane * stride + live * d],
                    live,
                )
                .unwrap();
                s
            })
            .collect();
        for (variant, p) in [
            (AttnVariant::Full, VariantParams::default()),
            (AttnVariant::ExactTopK, VariantParams { k_sel: 16, ..Default::default() }),
            (AttnVariant::Loki, VariantParams { k_sel: 16, d_sub: 4, ..Default::default() }),
            (AttnVariant::SparQ, VariantParams { k_sel: 16, d_sub: 4, ..Default::default() }),
            (AttnVariant::StreamingLlm, VariantParams { k_sel: 12, ..Default::default() }),
            (AttnVariant::PcaAttn, VariantParams { d_sub: 4, ..Default::default() }),
        ] {
            let a = decode_attend(&variant, shape, &q, &kc, &vc, stride, live, &p, None);
            let b = decode_attend_paged(&variant, &mut pool, &seqs, &q, &p, None);
            assert_eq!(a.context, b.context, "{variant:?} context must be bit-identical");
            assert_eq!(a.selected, b.selected, "{variant:?} selection must agree");
        }
        // H2O carries accumulator state: run both paths from equal states
        // and require the states to remain equal afterwards.
        let p = VariantParams { k_sel: 8, ..Default::default() };
        let mut state_flat: H2oState = vec![vec![0.0; live]; 3];
        let mut state_paged: H2oState = vec![vec![0.0; live]; 3];
        let a = decode_attend(
            &AttnVariant::H2O, shape, &q, &kc, &vc, stride, live, &p, Some(&mut state_flat),
        );
        let b = decode_attend_paged(
            &AttnVariant::H2O, &mut pool, &seqs, &q, &p, Some(&mut state_paged),
        );
        assert_eq!(a.context, b.context, "H2O context must be bit-identical");
        assert_eq!(state_flat, state_paged, "H2O accumulators must stay in lockstep");
        pool.check_invariants();
    }

    #[test]
    fn streaming_budget_holds_when_sinks_exceed_k_sel() {
        use crate::kvpool::{TieredKvPool, TieredPoolCfg};
        let (shape, q, kc, vc) = setup(1, 64, 8);
        let stride = 64 * 8;
        // sinks ≥ k_sel used to select sinks + 1 > k_sel slots.
        for (k_sel, sinks) in [(6usize, 16usize), (4, 4), (1, 9), (12, 64)] {
            let p = VariantParams { k_sel, sinks, ..Default::default() };
            let out = decode_attend(
                &AttnVariant::StreamingLlm,
                shape.clone(),
                &q,
                &kc,
                &vc,
                stride,
                64,
                &p,
                None,
            );
            let sel = &out.selected[0];
            assert!(
                sel.len() <= k_sel,
                "k_sel={k_sel} sinks={sinks}: selected {} > budget",
                sel.len()
            );
            assert!(sel.contains(&63), "newest token must stay in the window");
            // Paged path must enforce the identical cap.
            let mut pool = TieredKvPool::new(TieredPoolCfg {
                num_blocks: 16,
                block_size: 8,
                head_dim: 8,
                d_hot: 4,
                cold_resident_blocks: 0,
            });
            let s = pool.new_seq();
            pool.load_prefix(s, &kc[..64 * 8], &vc[..64 * 8], 64).unwrap();
            let paged =
                decode_attend_paged(&AttnVariant::StreamingLlm, &mut pool, &[s], &q, &p, None);
            assert_eq!(out.selected, paged.selected, "flat/paged selection must agree");
            assert_eq!(out.context, paged.context, "flat/paged context must be bit-identical");
        }
    }

    /// Satellite: flat Loki/PCAAttn clamp `d_sub.min(d)` while the paged
    /// path used to assert `d_sub <= d_hot` — the two must agree (and be
    /// bit-identical) at and beyond the hot-tier boundary.
    #[test]
    fn d_sub_clamp_agrees_between_flat_and_paged_at_boundaries() {
        use crate::kvpool::{TieredKvPool, TieredPoolCfg};
        let (shape, q, kc, vc) = setup(2, 32, 16);
        let (d, live, stride) = (16usize, 32usize, 32 * 16usize);
        let d_hot = 8usize;
        let mut pool = TieredKvPool::new(TieredPoolCfg {
            num_blocks: 32,
            block_size: 4,
            head_dim: d,
            d_hot,
            cold_resident_blocks: 0,
        });
        let seqs: Vec<_> = (0..2)
            .map(|lane| {
                let s = pool.new_seq();
                pool.load_prefix(
                    s,
                    &kc[lane * stride..lane * stride + live * d],
                    &vc[lane * stride..lane * stride + live * d],
                    live,
                )
                .unwrap();
                s
            })
            .collect();
        // Below, at, just past the hot tier, full width, and over-wide
        // (clamps to d): every case must stay in bit-lockstep.
        for d_sub in [4usize, d_hot, d_hot + 1, d, 100] {
            for variant in [AttnVariant::Loki, AttnVariant::PcaAttn] {
                let p = VariantParams { k_sel: 8, d_sub, ..Default::default() };
                let a =
                    decode_attend(&variant, shape.clone(), &q, &kc, &vc, stride, live, &p, None);
                let b = decode_attend_paged(&variant, &mut pool, &seqs, &q, &p, None);
                assert_eq!(a.selected, b.selected, "{variant:?} d_sub={d_sub} selection");
                assert_eq!(a.context, b.context, "{variant:?} d_sub={d_sub} context bits");
            }
        }
        pool.check_invariants();
    }

    /// Satellite: multi-step H2O lockstep. The single-step bitwise test
    /// cannot catch accumulator drift that only appears once `live`
    /// grows between steps; this drives appends between decode steps and
    /// requires flat and paged selections, contexts and accumulators to
    /// stay identical throughout.
    #[test]
    fn h2o_flat_and_paged_stay_in_lockstep_as_sequences_grow() {
        use crate::kvpool::{TieredKvPool, TieredPoolCfg};
        let (lanes, d, max_len) = (2usize, 8usize, 64usize);
        let stride = max_len * d;
        let mut rng = Xoshiro256::new(99);
        let mut kc = vec![0.0f32; lanes * stride];
        let mut vc = vec![0.0f32; lanes * stride];
        let mut pool = TieredKvPool::new(TieredPoolCfg {
            num_blocks: 64,
            block_size: 4,
            head_dim: d,
            d_hot: 4,
            cold_resident_blocks: 0,
        });
        let seqs: Vec<_> = (0..lanes).map(|_| pool.new_seq()).collect();
        let mut live = 0usize;
        let mut append = |kc: &mut Vec<f32>,
                          vc: &mut Vec<f32>,
                          pool: &mut TieredKvPool,
                          live: usize,
                          rng: &mut Xoshiro256| {
            for lane in 0..lanes {
                let k = rng.normal_vec(d);
                let v = rng.normal_vec(d);
                kc[lane * stride + live * d..lane * stride + (live + 1) * d].copy_from_slice(&k);
                vc[lane * stride + live * d..lane * stride + (live + 1) * d].copy_from_slice(&v);
                pool.append(seqs[lane], &k, &v).unwrap();
            }
        };
        for _ in 0..12 {
            append(&mut kc, &mut vc, &mut pool, live, &mut rng);
            live += 1;
        }
        let shape = AttnShape { lanes, head_dim: d, max_len };
        let p = VariantParams { k_sel: 6, ..Default::default() };
        let mut flat_state: H2oState = vec![vec![0.0; live]; lanes];
        let mut paged_state: H2oState = vec![vec![0.0; live]; lanes];
        for step in 0..8 {
            let q = rng.normal_vec(lanes * d);
            let a = decode_attend(
                &AttnVariant::H2O, shape.clone(), &q, &kc, &vc, stride, live, &p,
                Some(&mut flat_state),
            );
            let b = decode_attend_paged(
                &AttnVariant::H2O, &mut pool, &seqs, &q, &p, Some(&mut paged_state),
            );
            assert_eq!(a.selected, b.selected, "step {step}: selections diverged");
            assert_eq!(a.context, b.context, "step {step}: context bits diverged");
            assert_eq!(flat_state, paged_state, "step {step}: accumulators diverged");
            append(&mut kc, &mut vc, &mut pool, live, &mut rng);
            live += 1;
        }
        pool.check_invariants();
    }

    /// Satellite: H2O across a partial preemption. Truncating the paged
    /// sequence and re-appending the evicted rows (the engine's
    /// preempt-then-resume cycle at the data plane) must leave every
    /// subsequent H2O step bit-identical to an uninterrupted twin pool
    /// carrying the same accumulator.
    #[test]
    fn h2o_preempt_then_resume_stays_bitwise_identical() {
        use crate::kvpool::{TieredKvPool, TieredPoolCfg};
        let d = 8usize;
        let cfg = TieredPoolCfg {
            num_blocks: 32,
            block_size: 4,
            head_dim: d,
            d_hot: 4,
            cold_resident_blocks: 0,
        };
        let mut rng = Xoshiro256::new(123);
        let rows: Vec<(Vec<f32>, Vec<f32>)> =
            (0..20).map(|_| (rng.normal_vec(d), rng.normal_vec(d))).collect();
        let queries: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(d)).collect();
        let mut base = TieredKvPool::new(cfg);
        let mut vict = TieredKvPool::new(cfg);
        let (sb, sv) = (base.new_seq(), vict.new_seq());
        for (k, v) in &rows[..14] {
            base.append(sb, k, v).unwrap();
            vict.append(sv, k, v).unwrap();
        }
        let p = VariantParams { k_sel: 6, ..Default::default() };
        let mut st_base: H2oState = vec![vec![0.0; 14]];
        let mut st_vict: H2oState = vec![vec![0.0; 14]];
        // A few joint steps so the accumulators carry real history.
        for q in &queries[..3] {
            let a =
                decode_attend_paged(&AttnVariant::H2O, &mut base, &[sb], q, &p, Some(&mut st_base));
            let b =
                decode_attend_paged(&AttnVariant::H2O, &mut vict, &[sv], q, &p, Some(&mut st_vict));
            assert_eq!(a.context, b.context);
        }
        // Partial preemption on the victim: drop 2 tail blocks, then
        // resume by recomputing (re-appending) only the evicted rows.
        let kept = vict.truncate_tail_blocks(sv, 2);
        assert_eq!(kept, 8, "two 4-slot tail blocks evicted");
        for (k, v) in &rows[kept..14] {
            vict.append(sv, k, v).unwrap();
        }
        // Keep generating: both caches also grow with fresh appends.
        let mut live = 14;
        for (i, q) in queries[3..].iter().enumerate() {
            let a =
                decode_attend_paged(&AttnVariant::H2O, &mut base, &[sb], q, &p, Some(&mut st_base));
            let b =
                decode_attend_paged(&AttnVariant::H2O, &mut vict, &[sv], q, &p, Some(&mut st_vict));
            assert_eq!(a.selected, b.selected, "post-resume step {i}: selections diverged");
            assert_eq!(a.context, b.context, "post-resume step {i}: context bits diverged");
            assert_eq!(st_base, st_vict, "post-resume step {i}: accumulators diverged");
            let (k, v) = &rows[live];
            base.append(sb, k, v).unwrap();
            vict.append(sv, k, v).unwrap();
            live += 1;
        }
        base.check_invariants();
        vict.check_invariants();
    }

    #[test]
    fn pcaattn_uses_no_topk() {
        let (shape, q, kc, vc) = setup(1, 16, 8);
        let stride = 16 * 8;
        let p = VariantParams { d_sub: 2, ..Default::default() };
        let out = decode_attend(&AttnVariant::PcaAttn, shape, &q, &kc, &vc, stride, 16, &p, None);
        assert_eq!(out.selected[0].len(), 16);
        assert!(out.context.iter().all(|x| x.is_finite()));
    }
}
