//! Decode-step attention variants over the substrate cache.
//!
//! Each variant performs one generation step for a single (layer, lanes)
//! problem and reports (a) the context vectors, (b) which cache slots it
//! attended to (for the Fig-6 Jaccard agreement study) and (c) the data
//! movement tally. Variants mirror the paper's comparison set:
//!
//! | variant        | ranking signal                  | final attention |
//! |----------------|---------------------------------|-----------------|
//! | Full           | —                               | all slots       |
//! | ExactTopK      | exact scores (full D)           | top-k           |
//! | Loki           | approx scores (leading d comps) | top-k, full D   |
//! | SparQ          | approx scores (|q|-top d comps) | top-k, full D   |
//! | H2O            | accumulated attention mass      | hh ∪ recent     |
//! | StreamingLLM   | position (sinks + window)       | sinks ∪ window  |
//! | PCAAttn        | —                               | approx scores   |
//!
//! Loki/SparQ assume the cache already holds *rotated* keys K̂ = K·P
//! (rotation happens at append time in the serving path — Lemma 4.1 makes
//! exact attention in rotated space exact).

use super::kernels::{
    attend_rows_indexed, scores_indexed, DataMovement, FeatureAccess, Par,
};
use super::AttnShape;
use crate::linalg::softmax::softmax_masked_inplace;
use crate::linalg::topk::{top_k_indices, TopKAlgo};

#[derive(Clone, Debug, PartialEq)]
pub enum AttnVariant {
    Full,
    ExactTopK,
    Loki,
    SparQ,
    H2O,
    StreamingLlm,
    PcaAttn,
}

/// Knobs for a decode step (k/d given as absolute counts; callers convert
/// the paper's k_f·S / d_f·D fractions).
#[derive(Clone, Debug)]
pub struct VariantParams {
    /// Tokens selected for exact attention (top-k / H2O budget / window).
    pub k_sel: usize,
    /// Principal components used for approximate scoring (Loki/SparQ/PCAAttn).
    pub d_sub: usize,
    /// StreamingLLM attention sinks.
    pub sinks: usize,
    pub topk_algo: TopKAlgo,
    pub par: Par,
    pub threads: Option<usize>,
}

impl Default for VariantParams {
    fn default() -> Self {
        Self {
            k_sel: usize::MAX,
            d_sub: usize::MAX,
            sinks: 4,
            topk_algo: TopKAlgo::Heap,
            par: Par::Tiles2D,
            threads: None,
        }
    }
}

#[derive(Clone, Debug)]
pub struct DecodeOutput {
    /// `[lanes, head_dim]` context vectors.
    pub context: Vec<f32>,
    /// Selected slot indices per lane (what was attended to).
    pub selected: Vec<Vec<u32>>,
    pub movement: DataMovement,
}

/// Per-lane H2O accumulator state (attention mass per slot).
pub type H2oState = Vec<Vec<f32>>;

/// Run one decode step of `variant`.
///
/// * `q` — `[lanes, D]`, already rotated for Loki/SparQ/PCAAttn paths.
/// * `kc`/`vc` — caches with `lane_stride` floats between lanes.
/// * `live` — number of live slots.
/// * `h2o` — accumulator, updated in place when variant == H2O.
#[allow(clippy::too_many_arguments)]
pub fn decode_attend(
    variant: &AttnVariant,
    shape: AttnShape,
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    lane_stride: usize,
    live: usize,
    params: &VariantParams,
    mut h2o: Option<&mut H2oState>,
) -> DecodeOutput {
    let lanes = shape.lanes;
    let d = shape.head_dim;
    let scale = 1.0 / (d as f32).sqrt();
    let k_sel = params.k_sel.min(live);
    let mut movement = DataMovement::default();
    let mut scores = vec![0.0f32; lanes * live];

    let selected: Vec<Vec<u32>> = match variant {
        AttnVariant::Full => (0..lanes).map(|_| (0..live as u32).collect()).collect(),
        AttnVariant::ExactTopK | AttnVariant::Loki | AttnVariant::SparQ => {
            let feat = match variant {
                AttnVariant::ExactTopK => FeatureAccess::Full,
                AttnVariant::Loki => FeatureAccess::Prefix(params.d_sub.min(d)),
                AttnVariant::SparQ => {
                    // SparQ ranks feature dims by |q| per lane; a single
                    // shared gather set keeps the kernel contract simple —
                    // use lane 0's top-|q| dims (the benchmarked effect is
                    // the strided gather, not the dim choice).
                    let du = params.d_sub.min(d);
                    let mags: Vec<f32> = (0..d).map(|i| q[i].abs()).collect();
                    let mut ix = top_k_indices(TopKAlgo::Sort, &mags, du);
                    ix.sort_unstable();
                    FeatureAccess::Gather(ix.iter().map(|&i| i as u16).collect())
                }
                _ => unreachable!(),
            };
            movement.add(scores_indexed(
                shape, q, kc, lane_stride, live, &feat, scale, params.par,
                params.threads, &mut scores,
            ));
            (0..lanes)
                .map(|lane| {
                    top_k_indices(params.topk_algo, &scores[lane * live..(lane + 1) * live], k_sel)
                })
                .collect()
        }
        AttnVariant::H2O => {
            let state = h2o.as_deref_mut().expect("H2O needs accumulator state");
            assert_eq!(state.len(), lanes);
            let recent_w = k_sel - k_sel / 2;
            let hh_n = k_sel / 2;
            let recent_start = live.saturating_sub(recent_w);
            (0..lanes)
                .map(|lane| {
                    let acc = &state[lane];
                    let mut sel: Vec<u32> = (recent_start as u32..live as u32).collect();
                    if hh_n > 0 && recent_start > 0 {
                        let hh = top_k_indices(params.topk_algo, &acc[..recent_start], hh_n);
                        sel.extend(hh);
                    }
                    sel.sort_unstable();
                    sel
                })
                .collect()
        }
        AttnVariant::StreamingLlm => {
            let window = k_sel.saturating_sub(params.sinks).max(1);
            let start = live.saturating_sub(window);
            (0..lanes)
                .map(|_| {
                    let mut sel: Vec<u32> =
                        (0..params.sinks.min(start) as u32).collect();
                    sel.extend(start as u32..live as u32);
                    sel
                })
                .collect()
        }
        AttnVariant::PcaAttn => (0..lanes).map(|_| (0..live as u32).collect()).collect(),
    };

    // Final attention.
    let mut context = vec![0.0f32; lanes * d];
    match variant {
        AttnVariant::PcaAttn => {
            // Softmax directly over the d-dim approximate scores (App. E).
            let feat = FeatureAccess::Prefix(params.d_sub.min(d));
            movement.add(scores_indexed(
                shape, q, kc, lane_stride, live, &feat, scale, params.par,
                params.threads, &mut scores,
            ));
            let mask = vec![true; live];
            for lane in 0..lanes {
                let srow = &mut scores[lane * live..(lane + 1) * live];
                softmax_masked_inplace(srow, &mask);
                let vlane = &vc[lane * lane_stride..];
                let orow = &mut context[lane * d..(lane + 1) * d];
                for (j, &p) in srow.iter().enumerate() {
                    if p == 0.0 {
                        continue;
                    }
                    for (o, &v) in orow.iter_mut().zip(&vlane[j * d..(j + 1) * d]) {
                        *o += p * v;
                    }
                }
            }
            movement.cache_bytes_read += (lanes * live * d * 4) as u64; // V reads
        }
        _ => {
            movement.add(attend_rows_indexed(
                shape, q, kc, vc, lane_stride, &selected, scale, params.threads,
                &mut context,
            ));
        }
    }

    // H2O accumulator update: add this step's attention probabilities.
    if let AttnVariant::H2O = variant {
        let state = h2o.as_deref_mut().expect("checked above");
        for lane in 0..lanes {
            let sel = &selected[lane];
            let qlane = &q[lane * d..(lane + 1) * d];
            let klane = &kc[lane * lane_stride..];
            let mut probs: Vec<f32> = sel
                .iter()
                .map(|&j| {
                    let mut s = 0.0;
                    for i in 0..d {
                        s += qlane[i] * klane[j as usize * d + i];
                    }
                    s * scale
                })
                .collect();
            let mask = vec![true; probs.len()];
            softmax_masked_inplace(&mut probs, &mask);
            let acc = &mut state[lane];
            if acc.len() < live {
                acc.resize(live, 0.0);
            }
            for (&j, &p) in sel.iter().zip(&probs) {
                acc[j as usize] += p;
            }
        }
    }

    DecodeOutput { context, selected, movement }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn setup(lanes: usize, m: usize, d: usize) -> (AttnShape, Vec<f32>, Vec<f32>, Vec<f32>) {
        let shape = AttnShape { lanes, head_dim: d, max_len: m };
        let mut rng = Xoshiro256::new(7);
        (shape.clone(), rng.normal_vec(lanes * d), rng.normal_vec(lanes * m * d), rng.normal_vec(lanes * m * d))
    }

    #[test]
    fn exact_topk_with_k_eq_live_matches_full() {
        let (shape, q, kc, vc) = setup(2, 32, 8);
        let stride = 32 * 8;
        let p_full = VariantParams::default();
        let p_topk = VariantParams { k_sel: 32, ..Default::default() };
        let a = decode_attend(&AttnVariant::Full, shape, &q, &kc, &vc, stride, 32, &p_full, None);
        let b = decode_attend(&AttnVariant::ExactTopK, shape, &q, &kc, &vc, stride, 32, &p_topk, None);
        for (x, y) in a.context.iter().zip(&b.context) {
            assert!((x - y).abs() < 1e-4);
        }
    }

    #[test]
    fn loki_with_full_d_matches_exact_topk_selection() {
        let (shape, q, kc, vc) = setup(3, 64, 16);
        let stride = 64 * 16;
        let p = VariantParams { k_sel: 16, d_sub: 16, ..Default::default() };
        let a = decode_attend(&AttnVariant::ExactTopK, shape, &q, &kc, &vc, stride, 64, &p, None);
        let b = decode_attend(&AttnVariant::Loki, shape, &q, &kc, &vc, stride, 64, &p, None);
        for lane in 0..3 {
            let mut sa = a.selected[lane].clone();
            let mut sb = b.selected[lane].clone();
            sa.sort_unstable();
            sb.sort_unstable();
            assert_eq!(sa, sb);
        }
    }

    #[test]
    fn loki_moves_fewer_bytes_than_exact() {
        let (shape, q, kc, vc) = setup(2, 128, 32);
        let stride = 128 * 32;
        let exact = VariantParams { k_sel: 32, ..Default::default() };
        let loki = VariantParams { k_sel: 32, d_sub: 8, ..Default::default() };
        let a = decode_attend(&AttnVariant::ExactTopK, shape, &q, &kc, &vc, stride, 128, &exact, None);
        let b = decode_attend(&AttnVariant::Loki, shape, &q, &kc, &vc, stride, 128, &loki, None);
        assert!(b.movement.cache_bytes_read < a.movement.cache_bytes_read);
    }

    #[test]
    fn h2o_respects_budget_and_monotone_acc() {
        let (shape, q, kc, vc) = setup(2, 64, 8);
        let stride = 64 * 8;
        let mut state: H2oState = vec![vec![0.0; 64]; 2];
        // Give slot 3 a huge accumulated mass: must be kept as heavy hitter.
        state[0][3] = 100.0;
        let p = VariantParams { k_sel: 8, ..Default::default() };
        let out = decode_attend(&AttnVariant::H2O, shape, &q, &kc, &vc, stride, 64, &p, Some(&mut state));
        assert!(out.selected[0].contains(&3));
        assert_eq!(out.selected[0].len(), 8);
        // Recent window must include the newest slot.
        assert!(out.selected[0].contains(&63));
        // acc only grows.
        assert!(state[0][3] >= 100.0);
    }

    #[test]
    fn streaming_keeps_sinks_and_window() {
        let (shape, q, kc, vc) = setup(1, 64, 8);
        let stride = 64 * 8;
        let p = VariantParams { k_sel: 12, sinks: 4, ..Default::default() };
        let out = decode_attend(&AttnVariant::StreamingLlm, shape, &q, &kc, &vc, stride, 64, &p, None);
        let sel = &out.selected[0];
        for s in 0..4u32 {
            assert!(sel.contains(&s), "sink {s} missing");
        }
        assert!(sel.contains(&63));
        assert!(!sel.contains(&30), "middle token should be evicted");
    }

    #[test]
    fn pcaattn_uses_no_topk() {
        let (shape, q, kc, vc) = setup(1, 16, 8);
        let stride = 16 * 8;
        let p = VariantParams { d_sub: 2, ..Default::default() };
        let out = decode_attend(&AttnVariant::PcaAttn, shape, &q, &kc, &vc, stride, 16, &p, None);
        assert_eq!(out.selected[0].len(), 16);
        assert!(out.context.iter().all(|x| x.is_finite()));
    }
}
