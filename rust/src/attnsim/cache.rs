//! KV-cache container with the three append disciplines the stack
//! compares.
//!
//! Figure 6 (right) shows >80% of HuggingFace decode time going to
//! `torch.cat` KV-cache appends — each step reallocates a `[.., S+1, D]`
//! tensor and copies the whole history. [`AppendPolicy::Realloc`] models
//! that; [`AppendPolicy::InPlace`] is the preallocated write a serving
//! system does; [`AppendPolicy::Paged`] keeps the in-place write cost but
//! backs the cache with kvpool blocks allocated on demand, so resident
//! bytes track the *live* sequence length instead of `max_len` (the
//! discipline the serving engine's admission control assumes). The first
//! two are benchmarked by `repro-experiments fig6-append`, the paged one
//! by `cargo bench --bench kvpool_bench`.

use super::AttnShape;
use crate::kvpool::{BlockAllocator, BlockId};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppendPolicy {
    /// Preallocated `[lanes, max_len, D]`; append writes D floats per lane.
    InPlace,
    /// HuggingFace-style: reallocate `[lanes, len+1, D]` and copy history.
    Realloc,
    /// kvpool-backed: blocks of `block_size` token slots allocated on
    /// demand from a free list; append writes D floats per lane, resident
    /// bytes grow by whole blocks. Rows are addressed through the block
    /// table ([`KvCache::row`]); there is no contiguous lane view.
    Paged { block_size: usize },
}

/// Paged backend state: the gang-wide allocator and block table (all
/// lanes advance together in the substrate cache, so one table serves
/// every lane; per-sequence raggedness and sharing live in
/// [`crate::kvpool::TieredKvPool`]).
struct PagedGangStore {
    allocator: BlockAllocator,
    table: Vec<BlockId>,
}

/// One layer's K (or V) cache: row-major `[lanes, capacity, head_dim]`
/// for the flat policies, block-table-indexed for `Paged`.
pub struct KvCache {
    pub shape: AttnShape,
    policy: AppendPolicy,
    /// Live slots per cache (all lanes advance together here; per-lane
    /// raggedness lives in the coordinator, not the substrate).
    len: usize,
    capacity: usize,
    data: Vec<f32>,
    paged: Option<PagedGangStore>,
    /// Cumulative bytes copied by appends (the Fig-6-right metric).
    pub bytes_copied: u64,
}

impl KvCache {
    pub fn new(shape: AttnShape, policy: AppendPolicy) -> Self {
        let (capacity, paged) = match policy {
            AppendPolicy::InPlace => (shape.max_len, None),
            AppendPolicy::Realloc => (0, None), // grows per append
            AppendPolicy::Paged { block_size } => {
                assert!(block_size > 0, "block_size must be positive");
                let blocks = shape.max_len.div_ceil(block_size);
                (
                    shape.max_len,
                    Some(PagedGangStore {
                        allocator: BlockAllocator::new(blocks, block_size),
                        table: Vec::new(),
                    }),
                )
            }
        };
        let data = match policy {
            // Only InPlace pays its full footprint up front; Realloc and
            // Paged grow with the live length.
            AppendPolicy::InPlace => vec![0.0; shape.lanes * capacity * shape.head_dim],
            _ => Vec::new(),
        };
        Self { shape, policy, len: 0, capacity, data, paged, bytes_copied: 0 }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn policy(&self) -> AppendPolicy {
        self.policy
    }

    /// Raw storage. Flat policies: row-major `[lanes, capacity, head_dim]`
    /// (see [`Self::lane`]). Paged: block arena `[blocks, lanes,
    /// block_size, head_dim]` — address rows via [`Self::row`].
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn lane_stride(&self) -> usize {
        self.capacity * self.shape.head_dim
    }

    /// The rows of one lane: `[len, head_dim]` (prefix of capacity rows).
    /// Flat policies only — a paged cache has no contiguous lane view.
    pub fn lane(&self, lane: usize) -> &[f32] {
        assert!(
            !matches!(self.policy, AppendPolicy::Paged { .. }),
            "paged cache has no contiguous lane view; use row()/gather_lane_into()"
        );
        let s = self.lane_stride();
        &self.data[lane * s..lane * s + self.len * self.shape.head_dim]
    }

    /// One `[head_dim]` row by (lane, position), valid for every policy.
    pub fn row(&self, lane: usize, j: usize) -> &[f32] {
        assert!(j < self.len, "row {j} beyond live length {}", self.len);
        let d = self.shape.head_dim;
        match self.policy {
            AppendPolicy::Paged { block_size } => {
                let st = self.paged.as_ref().expect("paged store");
                let b = st.table[j / block_size] as usize;
                let off = (b * self.shape.lanes + lane) * block_size * d + (j % block_size) * d;
                &self.data[off..off + d]
            }
            _ => {
                let s = self.lane_stride();
                &self.data[lane * s + j * d..lane * s + (j + 1) * d]
            }
        }
    }

    /// Copy one lane's live rows (`[len, head_dim]`) into `out`, in
    /// position order — the policy-agnostic way to read a lane.
    pub fn gather_lane_into(&self, lane: usize, out: &mut [f32]) {
        let d = self.shape.head_dim;
        assert!(out.len() >= self.len * d, "output buffer too small");
        for j in 0..self.len {
            out[j * d..(j + 1) * d].copy_from_slice(self.row(lane, j));
        }
    }

    /// The block table backing a paged cache (None for flat policies).
    pub fn block_table(&self) -> Option<&[BlockId]> {
        self.paged.as_ref().map(|s| s.table.as_slice())
    }

    /// Bytes of backing storage currently allocated — the quantity the
    /// paged discipline optimizes (InPlace pays `lanes·max_len·D` up
    /// front; Paged pays per allocated block).
    pub fn resident_bytes(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Append one `[lanes, head_dim]` batch of rows.
    pub fn append(&mut self, rows: &[f32]) {
        let d = self.shape.head_dim;
        let lanes = self.shape.lanes;
        assert_eq!(rows.len(), lanes * d, "append shape mismatch");
        match self.policy {
            AppendPolicy::InPlace => {
                assert!(self.len < self.capacity, "cache full");
                let stride = self.lane_stride();
                for lane in 0..lanes {
                    let dst = lane * stride + self.len * d;
                    self.data[dst..dst + d].copy_from_slice(&rows[lane * d..(lane + 1) * d]);
                }
                self.bytes_copied += (lanes * d * 4) as u64;
            }
            AppendPolicy::Realloc => {
                // torch.cat semantics: brand-new buffer, full history copy.
                let new_cap = self.len + 1;
                let mut new_data = vec![0.0f32; lanes * new_cap * d];
                let old_stride = self.capacity * d;
                let new_stride = new_cap * d;
                for lane in 0..lanes {
                    let src = &self.data[lane * old_stride..lane * old_stride + self.len * d];
                    new_data[lane * new_stride..lane * new_stride + self.len * d]
                        .copy_from_slice(src);
                    new_data[lane * new_stride + self.len * d..lane * new_stride + new_cap * d]
                        .copy_from_slice(&rows[lane * d..(lane + 1) * d]);
                }
                self.bytes_copied += (lanes * new_cap * d * 4) as u64;
                self.data = new_data;
                self.capacity = new_cap;
            }
            AppendPolicy::Paged { block_size } => {
                assert!(self.len < self.capacity, "cache full");
                let off = self.len % block_size;
                let st = self.paged.as_mut().expect("paged store");
                if off == 0 {
                    // Block boundary: grant a fresh block and grow the
                    // arena up to its footprint.
                    let b = st.allocator.alloc().expect("allocator sized to capacity");
                    st.table.push(b);
                    let need = (b as usize + 1) * lanes * block_size * d;
                    if self.data.len() < need {
                        self.data.resize(need, 0.0);
                    }
                }
                let b = st.table[self.len / block_size] as usize;
                for lane in 0..lanes {
                    let dst = (b * lanes + lane) * block_size * d + off * d;
                    self.data[dst..dst + d].copy_from_slice(&rows[lane * d..(lane + 1) * d]);
                }
                self.bytes_copied += (lanes * d * 4) as u64;
            }
        }
        self.len += 1;
    }

    /// Bulk-load a prefill prefix (counts as one copy, like a real
    /// prefill). `rows` is `[lanes, len, head_dim]` row-major. Overflowing
    /// a bounded (InPlace/Paged) cache is a hard "cache full" error, the
    /// same condition `append` enforces.
    pub fn load_prefix(&mut self, rows: &[f32], len: usize) {
        let d = self.shape.head_dim;
        let lanes = self.shape.lanes;
        assert_eq!(rows.len(), lanes * len * d);
        match self.policy {
            AppendPolicy::Realloc => {
                self.capacity = len;
                self.data = vec![0.0; lanes * len * d];
            }
            AppendPolicy::InPlace => {
                assert!(
                    len <= self.capacity,
                    "cache full: prefix of {len} rows exceeds capacity {}",
                    self.capacity
                );
            }
            AppendPolicy::Paged { .. } => {
                assert!(
                    len <= self.capacity,
                    "cache full: prefix of {len} rows exceeds capacity {}",
                    self.capacity
                );
                assert_eq!(self.len, 0, "paged load_prefix requires an empty cache");
                // Route through append so block grants and byte accounting
                // stay in one place (totals match the flat one-shot copy).
                let mut batch = vec![0.0f32; lanes * d];
                for j in 0..len {
                    for lane in 0..lanes {
                        batch[lane * d..(lane + 1) * d]
                            .copy_from_slice(&rows[(lane * len + j) * d..(lane * len + j + 1) * d]);
                    }
                    self.append(&batch);
                }
                return;
            }
        }
        let stride = self.lane_stride();
        for lane in 0..lanes {
            let src = &rows[lane * len * d..(lane + 1) * len * d];
            self.data[lane * stride..lane * stride + len * d].copy_from_slice(src);
        }
        self.bytes_copied += (rows.len() * 4) as u64;
        self.len = len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn shape() -> AttnShape {
        AttnShape { lanes: 3, head_dim: 4, max_len: 8 }
    }

    #[test]
    fn inplace_and_realloc_agree_on_contents() {
        let mut rng = Xoshiro256::new(1);
        let mut a = KvCache::new(shape(), AppendPolicy::InPlace);
        let mut b = KvCache::new(shape(), AppendPolicy::Realloc);
        for _ in 0..5 {
            let rows = rng.normal_vec(3 * 4);
            a.append(&rows);
            b.append(&rows);
        }
        for lane in 0..3 {
            assert_eq!(a.lane(lane), b.lane(lane));
        }
    }

    #[test]
    fn paged_agrees_with_inplace_row_by_row() {
        let mut rng = Xoshiro256::new(4);
        // Generous max_len: the paged cache should only pay for live blocks.
        let shape = AttnShape { lanes: 3, head_dim: 4, max_len: 64 };
        let mut a = KvCache::new(shape, AppendPolicy::InPlace);
        let mut b = KvCache::new(shape, AppendPolicy::Paged { block_size: 3 });
        for _ in 0..7 {
            let rows = rng.normal_vec(3 * 4);
            a.append(&rows);
            b.append(&rows);
        }
        for lane in 0..3 {
            for j in 0..7 {
                assert_eq!(a.row(lane, j), b.row(lane, j), "lane {lane} row {j}");
            }
            let mut gathered = vec![0.0; 7 * 4];
            b.gather_lane_into(lane, &mut gathered);
            assert_eq!(a.lane(lane), &gathered[..]);
        }
        // Same append cost as InPlace (no history copies)…
        assert_eq!(a.bytes_copied, b.bytes_copied);
        // …but resident bytes cover 3 blocks of 3 slots, not max_len.
        assert_eq!(b.resident_bytes(), (3 * 3 * 3 * 4 * 4) as u64);
        assert!(b.resident_bytes() < a.resident_bytes());
        assert_eq!(b.block_table().unwrap().len(), 3);
    }

    #[test]
    fn realloc_copies_quadratically_more() {
        let mut a = KvCache::new(shape(), AppendPolicy::InPlace);
        let mut b = KvCache::new(shape(), AppendPolicy::Realloc);
        let rows = vec![1.0f32; 3 * 4];
        for _ in 0..8 {
            a.append(&rows);
            b.append(&rows);
        }
        // InPlace: n·D·4 per step. Realloc: n steps of (len+1)·D·4 ≈ n²/2.
        assert_eq!(a.bytes_copied, 8 * 3 * 4 * 4);
        assert_eq!(b.bytes_copied, (1 + 2 + 3 + 4 + 5 + 6 + 7 + 8) * 3 * 4 * 4);
    }

    #[test]
    #[should_panic(expected = "cache full")]
    fn inplace_overflow_panics() {
        let mut c = KvCache::new(shape(), AppendPolicy::InPlace);
        let rows = vec![0.0f32; 3 * 4];
        for _ in 0..9 {
            c.append(&rows);
        }
    }

    #[test]
    #[should_panic(expected = "cache full")]
    fn paged_overflow_panics() {
        let mut c = KvCache::new(shape(), AppendPolicy::Paged { block_size: 4 });
        let rows = vec![0.0f32; 3 * 4];
        for _ in 0..9 {
            c.append(&rows);
        }
    }

    #[test]
    fn load_prefix_then_append() {
        let mut rng = Xoshiro256::new(2);
        let prefix = rng.normal_vec(3 * 5 * 4);
        let mut c = KvCache::new(shape(), AppendPolicy::InPlace);
        c.load_prefix(&prefix, 5);
        assert_eq!(c.len(), 5);
        let rows = rng.normal_vec(3 * 4);
        c.append(&rows);
        assert_eq!(c.len(), 6);
        assert_eq!(&c.lane(1)[5 * 4..6 * 4], &rows[4..8]);
    }

    #[test]
    fn paged_load_prefix_matches_flat() {
        let mut rng = Xoshiro256::new(8);
        let prefix = rng.normal_vec(3 * 5 * 4);
        let mut a = KvCache::new(shape(), AppendPolicy::InPlace);
        let mut b = KvCache::new(shape(), AppendPolicy::Paged { block_size: 2 });
        a.load_prefix(&prefix, 5);
        b.load_prefix(&prefix, 5);
        assert_eq!(a.bytes_copied, b.bytes_copied, "prefill copy accounting must agree");
        for lane in 0..3 {
            for j in 0..5 {
                assert_eq!(a.row(lane, j), b.row(lane, j));
            }
        }
    }

    /// Regression: the seed's capacity check was `len <= capacity.max(len)`
    /// — always true — so an over-long prefill silently wrote out of the
    /// live region. Overflow must be a hard "cache full" failure.
    #[test]
    #[should_panic(expected = "cache full")]
    fn load_prefix_overflow_panics() {
        let mut rng = Xoshiro256::new(3);
        let prefix = rng.normal_vec(3 * 9 * 4); // 9 rows > max_len 8
        let mut c = KvCache::new(shape(), AppendPolicy::InPlace);
        c.load_prefix(&prefix, 9);
    }
}
