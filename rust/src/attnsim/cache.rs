//! KV-cache container with the two append disciplines the paper compares.
//!
//! Figure 6 (right) shows >80% of HuggingFace decode time going to
//! `torch.cat` KV-cache appends — each step reallocates a `[.., S+1, D]`
//! tensor and copies the whole history. [`AppendPolicy::Realloc`] models
//! that; [`AppendPolicy::InPlace`] is the preallocated write a serving
//! system (vLLM-style, or our coordinator) does. Both are benchmarked by
//! `repro-experiments fig6-append`.

use super::AttnShape;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AppendPolicy {
    /// Preallocated `[lanes, max_len, D]`; append writes D floats per lane.
    InPlace,
    /// HuggingFace-style: reallocate `[lanes, len+1, D]` and copy history.
    Realloc,
}

/// One layer's K (or V) cache: row-major `[lanes, capacity, head_dim]`.
pub struct KvCache {
    pub shape: AttnShape,
    policy: AppendPolicy,
    /// Live slots per cache (all lanes advance together here; per-lane
    /// raggedness lives in the coordinator, not the substrate).
    len: usize,
    capacity: usize,
    data: Vec<f32>,
    /// Cumulative bytes copied by appends (the Fig-6-right metric).
    pub bytes_copied: u64,
}

impl KvCache {
    pub fn new(shape: AttnShape, policy: AppendPolicy) -> Self {
        let capacity = match policy {
            AppendPolicy::InPlace => shape.max_len,
            AppendPolicy::Realloc => 0, // grows per append
        };
        Self {
            shape,
            policy,
            len: 0,
            capacity,
            data: vec![0.0; shape.lanes * capacity * shape.head_dim],
            bytes_copied: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn policy(&self) -> AppendPolicy {
        self.policy
    }

    /// Row-major `[lanes, len, head_dim]` view of the live region. With
    /// `InPlace` the stride between lanes is `max_len × D` (use
    /// [`Self::lane`]); with `Realloc` it is `len × D`.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn lane_stride(&self) -> usize {
        self.capacity * self.shape.head_dim
    }

    /// The rows of one lane: `[len, head_dim]` (prefix of capacity rows).
    pub fn lane(&self, lane: usize) -> &[f32] {
        let s = self.lane_stride();
        &self.data[lane * s..lane * s + self.len * self.shape.head_dim]
    }

    /// Append one `[lanes, head_dim]` batch of rows.
    pub fn append(&mut self, rows: &[f32]) {
        let d = self.shape.head_dim;
        assert_eq!(rows.len(), self.shape.lanes * d, "append shape mismatch");
        match self.policy {
            AppendPolicy::InPlace => {
                assert!(self.len < self.capacity, "cache full");
                let stride = self.lane_stride();
                for lane in 0..self.shape.lanes {
                    let dst = lane * stride + self.len * d;
                    self.data[dst..dst + d].copy_from_slice(&rows[lane * d..(lane + 1) * d]);
                }
                self.bytes_copied += (self.shape.lanes * d * 4) as u64;
            }
            AppendPolicy::Realloc => {
                // torch.cat semantics: brand-new buffer, full history copy.
                let new_cap = self.len + 1;
                let mut new_data = vec![0.0f32; self.shape.lanes * new_cap * d];
                let old_stride = self.capacity * d;
                let new_stride = new_cap * d;
                for lane in 0..self.shape.lanes {
                    let src = &self.data[lane * old_stride..lane * old_stride + self.len * d];
                    new_data[lane * new_stride..lane * new_stride + self.len * d]
                        .copy_from_slice(src);
                    new_data[lane * new_stride + self.len * d..lane * new_stride + new_cap * d]
                        .copy_from_slice(&rows[lane * d..(lane + 1) * d]);
                }
                self.bytes_copied += (self.shape.lanes * new_cap * d * 4) as u64;
                self.data = new_data;
                self.capacity = new_cap;
            }
        }
        self.len += 1;
    }

    /// Bulk-load a prefill prefix (counts as one copy, like a real prefill).
    pub fn load_prefix(&mut self, rows: &[f32], len: usize) {
        let d = self.shape.head_dim;
        assert_eq!(rows.len(), self.shape.lanes * len * d);
        if self.policy == AppendPolicy::Realloc {
            self.capacity = len;
            self.data = vec![0.0; self.shape.lanes * len * d];
        }
        assert!(len <= self.capacity.max(len));
        let stride = self.lane_stride();
        for lane in 0..self.shape.lanes {
            let src = &rows[lane * len * d..(lane + 1) * len * d];
            self.data[lane * stride..lane * stride + len * d].copy_from_slice(src);
        }
        self.bytes_copied += (rows.len() * 4) as u64;
        self.len = len;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn shape() -> AttnShape {
        AttnShape { lanes: 3, head_dim: 4, max_len: 8 }
    }

    #[test]
    fn inplace_and_realloc_agree_on_contents() {
        let mut rng = Xoshiro256::new(1);
        let mut a = KvCache::new(shape(), AppendPolicy::InPlace);
        let mut b = KvCache::new(shape(), AppendPolicy::Realloc);
        for _ in 0..5 {
            let rows = rng.normal_vec(3 * 4);
            a.append(&rows);
            b.append(&rows);
        }
        for lane in 0..3 {
            assert_eq!(a.lane(lane), b.lane(lane));
        }
    }

    #[test]
    fn realloc_copies_quadratically_more() {
        let mut a = KvCache::new(shape(), AppendPolicy::InPlace);
        let mut b = KvCache::new(shape(), AppendPolicy::Realloc);
        let rows = vec![1.0f32; 3 * 4];
        for _ in 0..8 {
            a.append(&rows);
            b.append(&rows);
        }
        // InPlace: n·D·4 per step. Realloc: n steps of (len+1)·D·4 ≈ n²/2.
        assert_eq!(a.bytes_copied, 8 * 3 * 4 * 4);
        assert_eq!(b.bytes_copied, (1 + 2 + 3 + 4 + 5 + 6 + 7 + 8) * 3 * 4 * 4);
    }

    #[test]
    #[should_panic(expected = "cache full")]
    fn inplace_overflow_panics() {
        let mut c = KvCache::new(shape(), AppendPolicy::InPlace);
        let rows = vec![0.0f32; 3 * 4];
        for _ in 0..9 {
            c.append(&rows);
        }
    }

    #[test]
    fn load_prefix_then_append() {
        let mut rng = Xoshiro256::new(2);
        let prefix = rng.normal_vec(3 * 5 * 4);
        let mut c = KvCache::new(shape(), AppendPolicy::InPlace);
        c.load_prefix(&prefix, 5);
        assert_eq!(c.len(), 5);
        let rows = rng.normal_vec(3 * 4);
        c.append(&rows);
        assert_eq!(c.len(), 6);
        assert_eq!(&c.lane(1)[5 * 4..6 * 4], &rows[4..8]);
    }
}
