//! Pure-Rust decode-attention substrate at arbitrary model shapes.
//!
//! The compiled PJRT path (see [`crate::runtime`]) runs the small served
//! model; the paper's *timing* studies, however, are at Llama2-13B shapes
//! (H=40, D=128, S up to 3584, batch 16) which no CPU-compiled toy model
//! reaches. This module reimplements every attention variant the paper
//! evaluates as explicit CPU kernels with byte-movement accounting, so the
//! Figure 6/7/16 experiments measure the *same effects* the paper measures
//! (data movement, parallelism structure, cache-append cost) at the same
//! tensor shapes:
//!
//! * [`kernels`] — indexed score / gather-attend kernels: feature-prefix
//!   slicing (Loki), arbitrary column gather (SparQ), dense-copy baseline
//!   (PyTorch-style), each serial / 1-D / 2-D threaded, plus block-table
//!   paged siblings (`scores_paged_lane` / `attend_rows_paged_lane`) that
//!   read a [`crate::kvpool`] arena bit-identically to the flat path.
//! * [`cache`]   — KV-cache with in-place ring append vs HuggingFace-style
//!   reallocating append (Fig. 6 right) vs kvpool-backed paged append.
//! * [`variants`] — full / exact-topk / Loki / H2O / StreamingLLM /
//!   SparQ / PCAAttn decode steps over the cache, with selected-index
//!   reporting for the Jaccard agreement study (Fig. 6 left); each also
//!   runs over paged KV state (`variants::decode_attend_paged`), where
//!   Loki ranks in the always-hot low-rank tier and gathers full-D pages
//!   for only the selected slots.

pub mod cache;
pub mod kernels;
pub mod rope;
pub mod variants;

pub use cache::{AppendPolicy, KvCache};
pub use kernels::{DataMovement, FeatureAccess};
pub use variants::{AttnVariant, DecodeOutput, VariantParams};

/// Shape of one attention layer's decode problem. `lanes` is batch·heads
/// flattened: every lane owns `max_len × head_dim` rows of K and V.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnShape {
    pub lanes: usize,
    pub head_dim: usize,
    pub max_len: usize,
}

/// Analytic bytes the attention *score path* moves for one decode step
/// over a context of `ctx_len` tokens, given the variant's cost
/// parameters from `DecodeVariant::score_cost_params()`.
///
/// `bytes_per_token` is the full K+V footprint of one token across all
/// layers/heads; the ranking scan reads only keys (half of it), scaled
/// by `d_frac` — the kept component fraction (Loki's low-rank scan) —
/// while the exact-attention gather reads K+V for the `j_sel` selected
/// tokens (every token when `j_sel` is `None`). This is the same
/// movement model the [`kernels::DataMovement`] counters measure
/// empirically; here it is closed-form so the engine can stamp it on
/// every `SchedRound` trace event without running a kernel.
pub fn score_path_bytes(
    ctx_len: usize,
    bytes_per_token: u64,
    d_frac: f64,
    j_sel: Option<usize>,
) -> u64 {
    let l = ctx_len as f64;
    let half = bytes_per_token as f64 / 2.0;
    let scan = l * half * d_frac;
    let gather = match j_sel {
        Some(j) => j.min(ctx_len) as f64 * bytes_per_token as f64,
        None => l * half, // exact attend: V read for every token
    };
    (scan + gather).round() as u64
}

impl AttnShape {
    pub fn llama2_13b(batch: usize, max_len: usize) -> Self {
        Self { lanes: batch * 40, head_dim: 128, max_len }
    }

    pub fn llama2_7b(batch: usize, max_len: usize) -> Self {
        Self { lanes: batch * 32, head_dim: 128, max_len }
    }

    pub fn cache_floats(&self) -> usize {
        self.lanes * self.max_len * self.head_dim
    }
}
