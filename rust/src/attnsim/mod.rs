//! Pure-Rust decode-attention substrate at arbitrary model shapes.
//!
//! The compiled PJRT path (see [`crate::runtime`]) runs the small served
//! model; the paper's *timing* studies, however, are at Llama2-13B shapes
//! (H=40, D=128, S up to 3584, batch 16) which no CPU-compiled toy model
//! reaches. This module reimplements every attention variant the paper
//! evaluates as explicit CPU kernels with byte-movement accounting, so the
//! Figure 6/7/16 experiments measure the *same effects* the paper measures
//! (data movement, parallelism structure, cache-append cost) at the same
//! tensor shapes:
//!
//! * [`kernels`] — indexed score / gather-attend kernels: feature-prefix
//!   slicing (Loki), arbitrary column gather (SparQ), dense-copy baseline
//!   (PyTorch-style), each serial / 1-D / 2-D threaded, plus block-table
//!   paged siblings (`scores_paged_lane` / `attend_rows_paged_lane`) that
//!   read a [`crate::kvpool`] arena bit-identically to the flat path.
//! * [`cache`]   — KV-cache with in-place ring append vs HuggingFace-style
//!   reallocating append (Fig. 6 right) vs kvpool-backed paged append.
//! * [`variants`] — full / exact-topk / Loki / H2O / StreamingLLM /
//!   SparQ / PCAAttn decode steps over the cache, with selected-index
//!   reporting for the Jaccard agreement study (Fig. 6 left); each also
//!   runs over paged KV state (`variants::decode_attend_paged`), where
//!   Loki ranks in the always-hot low-rank tier and gathers full-D pages
//!   for only the selected slots.

pub mod cache;
pub mod kernels;
pub mod rope;
pub mod variants;

pub use cache::{AppendPolicy, KvCache};
pub use kernels::{DataMovement, FeatureAccess};
pub use variants::{AttnVariant, DecodeOutput, VariantParams};

/// Shape of one attention layer's decode problem. `lanes` is batch·heads
/// flattened: every lane owns `max_len × head_dim` rows of K and V.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct AttnShape {
    pub lanes: usize,
    pub head_dim: usize,
    pub max_len: usize,
}

impl AttnShape {
    pub fn llama2_13b(batch: usize, max_len: usize) -> Self {
        Self { lanes: batch * 40, head_dim: 128, max_len }
    }

    pub fn llama2_7b(batch: usize, max_len: usize) -> Self {
        Self { lanes: batch * 32, head_dim: 128, max_len }
    }

    pub fn cache_floats(&self) -> usize {
        self.lanes * self.max_len * self.head_dim
    }
}
