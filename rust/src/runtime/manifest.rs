//! Typed view of `artifacts/manifest.json` — the python↔rust contract.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// The served model's architecture (mirrors python configs.ModelConfig).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_len: usize,
    pub rope_theta: f64,
}

/// One lowered graph: HLO file + positional input/output names.
#[derive(Clone, Debug)]
pub struct GraphSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub version: u64,
    pub model: ModelSpec,
    pub param_names: Vec<String>,
    pub batch_buckets: Vec<usize>,
    pub prefill_buckets: Vec<usize>,
    pub graphs: BTreeMap<String, GraphSpec>,
    pub weights_file: String,
    /// e.g. "wiki_pre" -> "pca_wiki_pre.npz"
    pub pca: BTreeMap<String, String>,
    pub default_pca: String,
    pub calibration_datasets: Vec<String>,
    pub family_models: Vec<String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let j = Json::parse_file(&path)
            .with_context(|| format!("parsing {}", path.display()))?;
        let m = j.req("model");
        let model = ModelSpec {
            name: m.req("name").as_str().unwrap_or("?").to_string(),
            vocab_size: m.req("vocab_size").as_usize().context("vocab_size")?,
            d_model: m.req("d_model").as_usize().context("d_model")?,
            n_layers: m.req("n_layers").as_usize().context("n_layers")?,
            n_heads: m.req("n_heads").as_usize().context("n_heads")?,
            head_dim: m.req("head_dim").as_usize().context("head_dim")?,
            d_ff: m.req("d_ff").as_usize().context("d_ff")?,
            max_len: m.req("max_len").as_usize().context("max_len")?,
            rope_theta: m.req("rope_theta").as_f64().unwrap_or(10000.0),
        };
        let strings = |key: &str| -> Result<Vec<String>> {
            Ok(j.req(key)
                .as_arr()
                .with_context(|| format!("{key} not an array"))?
                .iter()
                .filter_map(|x| x.as_str().map(|s| s.to_string()))
                .collect())
        };
        let usizes = |key: &str| -> Result<Vec<usize>> {
            Ok(j.req(key)
                .as_arr()
                .with_context(|| format!("{key} not an array"))?
                .iter()
                .filter_map(|x| x.as_usize())
                .collect())
        };
        let mut graphs = BTreeMap::new();
        for (name, g) in j.req("graphs").as_obj().context("graphs")? {
            let names = |key: &str| -> Vec<String> {
                g.req(key)
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_str().map(|s| s.to_string()))
                    .collect()
            };
            graphs.insert(
                name.clone(),
                GraphSpec {
                    name: name.clone(),
                    file: g.req("file").as_str().context("graph file")?.to_string(),
                    inputs: names("inputs"),
                    outputs: names("outputs"),
                },
            );
        }
        let mut pca = BTreeMap::new();
        for (k, v) in j.req("pca").as_obj().context("pca")? {
            if let Some(s) = v.as_str() {
                pca.insert(k.clone(), s.to_string());
            }
        }
        let man = Manifest {
            dir: dir.to_path_buf(),
            version: j.req("version").as_usize().unwrap_or(0) as u64,
            model,
            param_names: strings("param_names")?,
            batch_buckets: usizes("batch_buckets")?,
            prefill_buckets: usizes("prefill_buckets")?,
            graphs,
            weights_file: j.req("weights").as_str().context("weights")?.to_string(),
            pca,
            default_pca: j.req("default_pca").as_str().unwrap_or("wiki_pre").to_string(),
            calibration_datasets: strings("calibration_datasets")?,
            family_models: strings("family_models").unwrap_or_default(),
        };
        man.validate()?;
        Ok(man)
    }

    fn validate(&self) -> Result<()> {
        if self.param_names.is_empty() {
            bail!("manifest has no param_names");
        }
        for b in &self.batch_buckets {
            for required in ["decode_full", "decode_loki", "decode_h2o", "decode_pcaattn"] {
                let g = format!("{required}_b{b}");
                if !self.graphs.contains_key(&g) {
                    bail!("manifest missing graph {g}");
                }
            }
        }
        for (_, g) in &self.graphs {
            if !self.dir.join(&g.file).exists() {
                bail!("graph file missing: {}", g.file);
            }
        }
        if !self.dir.join(&self.weights_file).exists() {
            bail!("weights file missing: {}", self.weights_file);
        }
        Ok(())
    }

    /// Smallest batch bucket that can hold `n` lanes (or the largest one).
    pub fn pick_batch_bucket(&self, n: usize) -> usize {
        let mut buckets = self.batch_buckets.clone();
        buckets.sort_unstable();
        for &b in &buckets {
            if b >= n {
                return b;
            }
        }
        *buckets.last().expect("no batch buckets")
    }

    /// Smallest prefill bucket that fits a prompt of `len` tokens.
    pub fn pick_prefill_bucket(&self, len: usize) -> Option<usize> {
        let mut buckets = self.prefill_buckets.clone();
        buckets.sort_unstable();
        buckets.into_iter().find(|&p| p >= len)
    }

    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs
            .get(name)
            .with_context(|| format!("graph {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::artifacts_dir;

    #[test]
    fn loads_real_manifest() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).expect("manifest should load");
        assert!(m.model.n_layers >= 1);
        assert_eq!(m.param_names.len(), 2 + 9 * m.model.n_layers + 1);
        assert!(m.pca.contains_key(&m.default_pca));
    }

    #[test]
    fn bucket_selection() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.pick_batch_bucket(1), 1);
        assert_eq!(m.pick_batch_bucket(3), 8);
        assert_eq!(m.pick_batch_bucket(100), 8);
        assert_eq!(m.pick_prefill_bucket(10), Some(128));
        assert_eq!(m.pick_prefill_bucket(200), Some(512));
        assert_eq!(m.pick_prefill_bucket(100_000), None);
    }
}
