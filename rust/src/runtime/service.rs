//! Channel-based runtime service: the `Send + Sync` face of the
//! thread-confined [`RuntimeStack`].
//!
//! [`RuntimeService::start`] spawns the runtime thread (which owns all
//! PJRT state) and hands out cloneable [`RuntimeHandle`]s. Every call is a
//! synchronous round-trip over an mpsc pair — mirroring the single-device
//! execution discipline of a real serving node: the coordinator decides
//! *what* to run next (prefill vs decode vs inject), the runtime thread
//! runs exactly one graph at a time.

use std::path::PathBuf;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use super::manifest::Manifest;
use super::stack::{DecodeRequest, RuntimeStack, RuntimeStats, StateId};

enum Req {
    Prefill {
        pca: String,
        prompts: Vec<Vec<i32>>,
        reply: Sender<Result<(StateId, Vec<Vec<f32>>)>>,
    },
    Decode {
        req: DecodeRequest,
        reply: Sender<Result<Vec<Vec<f32>>>>,
    },
    Inject {
        gang: StateId,
        lane: StateId,
        idx: usize,
        reply: Sender<Result<()>>,
    },
    Free(StateId),
    Warmup {
        graphs: Vec<String>,
        reply: Sender<Result<()>>,
    },
    Stats {
        reply: Sender<RuntimeStats>,
    },
    Shutdown,
}

/// Cloneable, thread-safe handle to the runtime thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: Sender<Req>,
}

// Sender<T> is Send but not Sync; wrap sends behind a clone-per-call
// pattern: each method clones tx (cheap) — Sender is Send+Clone, and
// RuntimeHandle is used per-thread after cloning.
impl RuntimeHandle {
    pub fn prefill(&self, pca: &str, prompts: Vec<Vec<i32>>) -> Result<(StateId, Vec<Vec<f32>>)> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Prefill { pca: pca.to_string(), prompts, reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))?
    }

    pub fn decode(&self, req: DecodeRequest) -> Result<Vec<Vec<f32>>> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Decode { req, reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))?
    }

    pub fn inject(&self, gang: StateId, lane: StateId, idx: usize) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Inject { gang, lane, idx, reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))?
    }

    pub fn free(&self, id: StateId) {
        let _ = self.tx.send(Req::Free(id));
    }

    /// Pre-compile graphs so first-request latency excludes compilation.
    pub fn warmup(&self, graphs: Vec<String>) -> Result<()> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Warmup { graphs, reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))?
    }

    pub fn stats(&self) -> Result<RuntimeStats> {
        let (reply, rx) = channel();
        self.tx
            .send(Req::Stats { reply })
            .map_err(|_| anyhow!("runtime thread gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime thread dropped reply"))
    }
}

/// Owns the runtime thread; dropping shuts it down.
pub struct RuntimeService {
    tx: Sender<Req>,
    pub manifest: Manifest,
    join: Option<std::thread::JoinHandle<()>>,
    /// Serializes handle creation (Sender clone) — keeps RuntimeService Sync.
    _guard: Mutex<()>,
}

impl RuntimeService {
    /// Spawn the runtime thread over the given artifacts directory.
    pub fn start(dir: PathBuf) -> Result<Self> {
        // Parse the manifest on the caller thread too (host-side data) so
        // schedulers can make bucket decisions without a round-trip.
        let manifest = Manifest::load(&dir)?;
        let (tx, rx) = channel::<Req>();
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        let join = std::thread::Builder::new()
            .name("loki-runtime".to_string())
            .spawn(move || {
                let stack = match RuntimeStack::load(&dir) {
                    Ok(s) => {
                        let _ = ready_tx.send(Ok(()));
                        s
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        Req::Prefill { pca, prompts, reply } => {
                            let _ = reply.send(stack.prefill(&pca, &prompts));
                        }
                        Req::Decode { req, reply } => {
                            let _ = reply.send(stack.decode(&req));
                        }
                        Req::Inject { gang, lane, idx, reply } => {
                            let _ = reply.send(stack.inject(gang, lane, idx));
                        }
                        Req::Free(id) => stack.free(id),
                        Req::Warmup { graphs, reply } => {
                            let mut res = Ok(());
                            for g in &graphs {
                                if let Err(e) = stack.executable(g) {
                                    res = Err(e);
                                    break;
                                }
                            }
                            let _ = reply.send(res);
                        }
                        Req::Stats { reply } => {
                            let _ = reply.send(stack.stats.borrow().clone());
                        }
                        Req::Shutdown => break,
                    }
                }
            })
            .expect("spawn runtime thread");
        ready_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during load"))??;
        Ok(Self { tx, manifest, join: Some(join), _guard: Mutex::new(()) })
    }

    pub fn handle(&self) -> RuntimeHandle {
        let _g = self._guard.lock().unwrap();
        RuntimeHandle { tx: self.tx.clone() }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
