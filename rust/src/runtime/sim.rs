//! Deterministic simulated runtime for hermetic engine/serving tests.
//!
//! [`SimRuntime`] implements [`DecodeBackend`] with no device, no
//! artifacts and no floating-point model: each lane's logits are a pure
//! hash of that lane's full token history (seeded by [`SimCfg::seed`]).
//! Two consequences make it the right substrate for scheduler tests:
//!
//! 1. **Batch independence** — a lane's logits do not depend on which
//!    other lanes share the gang, so scheduling decisions (injection
//!    order, padding lanes, preemption) can never leak into outputs.
//!    Any output divergence a test observes is a real engine bug.
//! 2. **History purity** — re-prefilling `prompt ++ produced` after a
//!    preemption reconstructs the exact decode distribution, which is
//!    precisely the property the engine's preempt/resume state machine
//!    claims (byte-identical resumption via prefix recompute).
//!
//! The sim is intentionally *not* a language model: logits are noise.
//! Tests assert scheduling/memory invariants and bit-level determinism,
//! never text quality.

use std::collections::HashMap;
use std::sync::Mutex;

use anyhow::{anyhow, ensure, Result};

use crate::kvpool::chain_hash;

use super::backend::DecodeBackend;
use super::stack::{DecodeRequest, StateId};

#[derive(Clone, Copy, Debug)]
pub struct SimCfg {
    /// Logit width — the simulated vocabulary. Keep ≤ 256 so greedy /
    /// sampled ids stay valid bytes for `ByteTokenizer::decode`.
    pub vocab: usize,
    /// Folded into every logit hash: two sims with different seeds are
    /// different "models".
    pub seed: u64,
}

impl Default for SimCfg {
    fn default() -> Self {
        Self { vocab: 96, seed: 0x51D0_D00D }
    }
}

/// A deterministic, thread-safe, device-free [`DecodeBackend`].
pub struct SimRuntime {
    cfg: SimCfg,
    inner: Mutex<SimState>,
}

#[derive(Default)]
struct SimState {
    next: StateId,
    /// State id → per-lane token histories (prompt + every decoded token).
    // lint:allow(nondet-iter): keyed access only (by StateId), never iterated
    states: HashMap<StateId, Vec<Vec<i32>>>,
}

impl SimRuntime {
    pub fn new(cfg: SimCfg) -> Self {
        assert!((2..=256).contains(&cfg.vocab), "sim vocab must be in 2..=256");
        Self { cfg, inner: Mutex::new(SimState::default()) }
    }

    /// Logits for one lane — a pure function of (seed, history).
    fn logits(&self, history: &[i32]) -> Vec<f32> {
        let h = chain_hash(self.cfg.seed, history);
        (0..self.cfg.vocab as u64).map(|v| unit_logit(h, v)).collect()
    }
}

/// SplitMix-style finalizer → one f32 in [-4, 4).
fn unit_logit(h: u64, v: u64) -> f32 {
    let mut x = h ^ v.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    ((x >> 11) as f64 / (1u64 << 53) as f64 * 8.0 - 4.0) as f32
}

impl DecodeBackend for SimRuntime {
    fn prefill(&self, _pca: &str, prompts: Vec<Vec<i32>>) -> Result<(StateId, Vec<Vec<f32>>)> {
        ensure!(!prompts.is_empty(), "sim: empty prefill batch");
        let logits = prompts.iter().map(|p| self.logits(p)).collect();
        let mut st = self.inner.lock().unwrap();
        st.next += 1;
        let id = st.next;
        st.states.insert(id, prompts);
        Ok((id, logits))
    }

    fn prefill_extend(
        &self,
        _pca: &str,
        state: StateId,
        full: &[i32],
        done: usize,
        n: usize,
    ) -> Result<(StateId, Vec<f32>)> {
        let upto = (done + n).min(full.len());
        ensure!(done < upto, "sim: empty prefill_extend chunk");
        let mut st = self.inner.lock().unwrap();
        if done == 0 {
            st.next += 1;
            let id = st.next;
            st.states.insert(id, vec![full[..upto].to_vec()]);
            drop(st);
            return Ok((id, self.logits(&full[..upto])));
        }
        let lanes = st
            .states
            .get_mut(&state)
            .ok_or_else(|| anyhow!("sim: prefill_extend of unknown state {state}"))?;
        ensure!(lanes.len() == 1, "sim: prefill_extend on a gang of {}", lanes.len());
        ensure!(
            lanes[0].len() == done && lanes[0] == &full[..done],
            "sim: prefill_extend prefix mismatch at {done}"
        );
        lanes[0].extend_from_slice(&full[done..upto]);
        drop(st);
        Ok((state, self.logits(&full[..upto])))
    }

    fn decode(&self, req: DecodeRequest) -> Result<Vec<Vec<f32>>> {
        let mut st = self.inner.lock().unwrap();
        let lanes = st
            .states
            .get_mut(&req.state)
            .ok_or_else(|| anyhow!("sim: decode of unknown state {}", req.state))?;
        ensure!(
            lanes.len() == req.tokens.len(),
            "sim: token batch {} vs state lanes {}",
            req.tokens.len(),
            lanes.len()
        );
        for (lane, &tok) in lanes.iter_mut().zip(&req.tokens) {
            lane.push(tok);
        }
        Ok(lanes.iter().map(|lane| self.logits(lane)).collect())
    }

    fn inject(&self, gang: StateId, lane: StateId, idx: usize) -> Result<()> {
        let mut st = self.inner.lock().unwrap();
        let mut src = st
            .states
            .remove(&lane)
            .ok_or_else(|| anyhow!("sim: inject from unknown state {lane}"))?;
        ensure!(!src.is_empty(), "sim: inject from empty state {lane}");
        let history = src.swap_remove(0);
        let dst = st
            .states
            .get_mut(&gang)
            .ok_or_else(|| anyhow!("sim: inject into unknown gang {gang}"))?;
        ensure!(idx < dst.len(), "sim: lane {idx} out of range for gang of {}", dst.len());
        dst[idx] = history;
        Ok(())
    }

    fn free(&self, id: StateId) {
        if let Ok(mut st) = self.inner.lock() {
            st.states.remove(&id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sim() -> SimRuntime {
        SimRuntime::new(SimCfg { vocab: 32, seed: 7 })
    }

    fn greedy(logits: &[f32]) -> i32 {
        crate::model::argmax(logits) as i32
    }

    #[test]
    fn logits_are_a_pure_function_of_history() {
        let s = sim();
        let a = s.logits(&[1, 2, 3]);
        let b = s.logits(&[1, 2, 3]);
        assert_eq!(a, b);
        assert_ne!(a, s.logits(&[1, 2, 4]), "history must matter");
        assert_ne!(a, s.logits(&[3, 2, 1]), "order must matter");
        let other = SimRuntime::new(SimCfg { vocab: 32, seed: 8 });
        assert_ne!(a, other.logits(&[1, 2, 3]), "seed must matter");
    }

    #[test]
    fn decode_is_batch_independent() {
        // The same lane history produces the same logits whether it sits
        // alone or beside other lanes — the property that makes engine
        // scheduling invisible in outputs.
        let s = sim();
        let (solo, l_solo) = s.prefill("pca", vec![vec![5, 6]]).unwrap();
        let (duo, l_duo) = s.prefill("pca", vec![vec![5, 6], vec![9, 9, 9]]).unwrap();
        assert_eq!(l_solo[0], l_duo[0]);
        let d_solo = s
            .decode(DecodeRequest {
                state: solo,
                variant: crate::runtime::DecodeVariant::Full,
                tokens: vec![11],
            })
            .unwrap();
        let d_duo = s
            .decode(DecodeRequest {
                state: duo,
                variant: crate::runtime::DecodeVariant::Full,
                tokens: vec![11, 3],
            })
            .unwrap();
        assert_eq!(d_solo[0], d_duo[0]);
    }

    #[test]
    fn prefix_recompute_reconstructs_the_decode_distribution() {
        // Decode a few greedy tokens, then "resume" from a fresh prefill
        // of prompt ++ produced: the next logits must match bit-for-bit.
        let s = sim();
        let prompt = vec![2, 4, 8];
        let (st, l0) = s.prefill("pca", vec![prompt.clone()]).unwrap();
        let mut produced = Vec::new();
        let mut next = greedy(&l0[0]);
        for _ in 0..5 {
            let l = s
                .decode(DecodeRequest {
                    state: st,
                    variant: crate::runtime::DecodeVariant::Full,
                    tokens: vec![next],
                })
                .unwrap();
            produced.push(next);
            next = greedy(&l[0]);
        }
        let mut resumed = prompt.clone();
        resumed.extend_from_slice(&produced);
        let (st2, _) = s.prefill("pca", vec![resumed]).unwrap();
        let l_resume = s
            .decode(DecodeRequest {
                state: st2,
                variant: crate::runtime::DecodeVariant::Full,
                tokens: vec![next],
            })
            .unwrap();
        let l_orig = s
            .decode(DecodeRequest {
                state: st,
                variant: crate::runtime::DecodeVariant::Full,
                tokens: vec![next],
            })
            .unwrap();
        assert_eq!(l_orig[0], l_resume[0], "resume diverged from uncontended decode");
    }

    #[test]
    fn chunked_prefill_extend_matches_monolithic_prefill() {
        // Growing a state chunk-by-chunk must land on the same history —
        // and therefore bit-identical logits — as one monolithic prefill.
        let s = sim();
        let full: Vec<i32> = (0..23).map(|i| ((i * 5 + 1) % 32) as i32).collect();
        let (_, l_mono) = s.prefill("pca", vec![full.clone()]).unwrap();
        let mut state = 0;
        let mut done = 0usize;
        let mut last = Vec::new();
        for chunk in [4usize, 7, 1, 999] {
            let (id, l) = s.prefill_extend("pca", state, &full, done, chunk).unwrap();
            state = id;
            done = (done + chunk).min(full.len());
            last = l;
        }
        assert_eq!(done, full.len());
        assert_eq!(last, l_mono[0], "chunked prefill diverged from monolithic");
        // The chunked state decodes like a monolithic one.
        let d = s
            .decode(DecodeRequest {
                state,
                variant: crate::runtime::DecodeVariant::Full,
                tokens: vec![9],
            })
            .unwrap();
        let mut hist = full.clone();
        hist.push(9);
        assert_eq!(d[0], s.logits(&hist));
    }

    #[test]
    fn default_prefill_extend_emulation_matches_exact_override() {
        // A backend without an incremental entry point inherits the
        // re-prefill emulation; it must produce the same logits as the
        // sim's exact O(n) append (both are history-pure).
        struct NoExtend(SimRuntime);
        impl DecodeBackend for NoExtend {
            fn prefill(
                &self,
                pca: &str,
                prompts: Vec<Vec<i32>>,
            ) -> Result<(StateId, Vec<Vec<f32>>)> {
                self.0.prefill(pca, prompts)
            }
            fn decode(&self, req: DecodeRequest) -> Result<Vec<Vec<f32>>> {
                self.0.decode(req)
            }
            fn inject(&self, gang: StateId, lane: StateId, idx: usize) -> Result<()> {
                self.0.inject(gang, lane, idx)
            }
            fn free(&self, id: StateId) {
                self.0.free(id)
            }
        }
        let exact = sim();
        let emu = NoExtend(sim());
        let full: Vec<i32> = (0..17).map(|i| ((i * 3 + 2) % 32) as i32).collect();
        let (mut se, mut de) = (0, 0usize);
        let (mut sm, mut dm) = (0, 0usize);
        for chunk in [5usize, 5, 5, 5] {
            let (ide, le) = exact.prefill_extend("pca", se, &full, de, chunk).unwrap();
            let (idm, lm) = emu.prefill_extend("pca", sm, &full, dm, chunk).unwrap();
            assert_eq!(le, lm, "emulation diverged at done={de}");
            se = ide;
            de = (de + chunk).min(full.len());
            sm = idm;
            dm = (dm + chunk).min(full.len());
        }
        assert_eq!(de, full.len());
    }

    #[test]
    fn inject_replaces_gang_lane_and_consumes_source() {
        let s = sim();
        let (gang, _) = s.prefill("pca", vec![vec![0], vec![0], vec![0]]).unwrap();
        let (lane, _) = s.prefill("pca", vec![vec![7, 7]]).unwrap();
        s.inject(gang, lane, 1).unwrap();
        let l = s
            .decode(DecodeRequest {
                state: gang,
                variant: crate::runtime::DecodeVariant::Full,
                tokens: vec![1, 2, 3],
            })
            .unwrap();
        assert_eq!(l[1], s.logits(&[7, 7, 2]));
        assert!(s.decode(DecodeRequest {
            state: lane,
            variant: crate::runtime::DecodeVariant::Full,
            tokens: vec![1],
        })
        .is_err(), "source state must be consumed");
    }
}
