//! PJRT runtime: load AOT artifacts, compile HLO text, execute graphs.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and therefore
//! thread-confined. The runtime mirrors a single-accelerator serving
//! system: one **runtime thread** owns the client, the compiled
//! executables, the weight buffers and every KV-cache gang state (all
//! device-resident `PjRtBuffer`s); the rest of the system talks to it
//! through the `Send + Sync` [`service::RuntimeService`] handle. Decode
//! steps feed the previous step's output buffers straight back in as
//! inputs — the host only ever sees tokens, lengths and logits. (The
//! vendored `xla` crate carries a one-line patch setting
//! `ExecuteOptions::untuple_result = true`, without which PJRT returns a
//! single fused tuple buffer that could not be fed back; see DESIGN.md.)
//!
//! Layering:
//! * [`manifest`] — typed view of `artifacts/manifest.json` (the contract
//!   aot.py writes: graph input/output orders, buckets, file names).
//! * [`stack`]    — `RuntimeStack`, the thread-confined engine.
//! * [`service`]  — channel-based handle + the runtime thread main loop.

pub mod hlo_inspect;
pub mod manifest;
pub mod service;
pub mod stack;

pub use manifest::{GraphSpec, Manifest, ModelSpec};
pub use service::{RuntimeHandle, RuntimeService};
pub use stack::{DecodeRequest, DecodeVariant, RuntimeStack, StateId};
