//! PJRT runtime: load AOT artifacts, compile HLO text, execute graphs.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based and therefore
//! thread-confined. The runtime mirrors a single-accelerator serving
//! system: one **runtime thread** owns the client, the compiled
//! executables, the weight buffers and every KV-cache gang state (all
//! device-resident `PjRtBuffer`s); the rest of the system talks to it
//! through the `Send + Sync` [`service::RuntimeService`] handle. Decode
//! steps feed the previous step's output buffers straight back in as
//! inputs — the host only ever sees tokens, lengths and logits. (The
//! vendored `xla` crate carries a one-line patch setting
//! `ExecuteOptions::untuple_result = true`, without which PJRT returns a
//! single fused tuple buffer that could not be fed back; see DESIGN.md.)
//!
//! Layering:
//! * [`manifest`] — typed view of `artifacts/manifest.json` (the contract
//!   aot.py writes: graph input/output orders, buckets, file names).
//! * [`stack`]    — `RuntimeStack`, the thread-confined engine.
//! * [`service`]  — channel-based handle + the runtime thread main loop.
//! * [`backend`]  — the [`backend::DecodeBackend`] trait the coordinator
//!   schedules against (prefill / decode / inject), implemented by
//!   [`RuntimeHandle`].
//! * [`sim`]      — [`sim::SimRuntime`], a deterministic artifact-free
//!   backend whose logits are a pure hash of each lane's token history;
//!   the substrate of the hermetic engine/serving test harness.

pub mod backend;
pub mod hlo_inspect;
pub mod manifest;
pub mod service;
pub mod sim;
pub mod stack;

pub use backend::DecodeBackend;
pub use manifest::{GraphSpec, Manifest, ModelSpec};
pub use service::{RuntimeHandle, RuntimeService};
pub use sim::{SimCfg, SimRuntime};
pub use stack::{DecodeRequest, DecodeVariant, RuntimeStack, StateId};
