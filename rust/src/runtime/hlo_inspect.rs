//! HLO-text static analyzer: op census, FLOP and memory-traffic estimates
//! for the AOT graphs — the tool behind the L2 §Perf claims ("no
//! recomputation, decode lowers to one dot per score stage") and the
//! `repro-experiments hlo-cost` report.
//!
//! This is a lightweight line-oriented parser of the HLO text format
//! (`name = type[shape] opcode(args), attrs`), not a full grammar: it
//! extracts opcode, result shape and operand count, which is enough for
//! cost accounting. Shapes like `f32[4,8,3,768,64]{...}` are parsed into
//! element counts.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

/// One parsed HLO instruction.
#[derive(Clone, Debug)]
pub struct HloInstr {
    pub name: String,
    pub opcode: String,
    /// Elements in the (first) result shape; tuples sum their leaves.
    pub out_elems: u64,
    /// Bytes of the result (f32/s32 = 4, f64 = 8, pred/s8 = 1, f16 = 2).
    pub out_bytes: u64,
}

/// Census of a whole module.
#[derive(Clone, Debug, Default)]
pub struct HloReport {
    pub module: String,
    pub instr_count: usize,
    pub by_opcode: BTreeMap<String, usize>,
    /// FLOPs estimated for dot ops (2·M·N·K) and elementwise ops (1/elem).
    pub flops: u64,
    /// Sum of all instruction result bytes — an upper bound on intra-graph
    /// traffic (XLA fusion eliminates much of it; relative comparisons
    /// between graphs remain meaningful).
    pub result_bytes: u64,
    pub dot_count: usize,
    pub while_count: usize,
    pub param_bytes: u64,
}

fn elem_size(ty: &str) -> u64 {
    match ty {
        "f64" | "s64" | "u64" | "c64" => 8,
        "f32" | "s32" | "u32" => 4,
        "f16" | "bf16" | "s16" | "u16" => 2,
        "pred" | "s8" | "u8" => 1,
        _ => 4,
    }
}

/// Parse every `ty[d0,d1,...]` occurrence in a shape string; returns
/// (total elements, total bytes) across tuple leaves.
fn parse_shape(s: &str) -> (u64, u64) {
    let mut elems = 0u64;
    let mut bytes = 0u64;
    let b = s.as_bytes();
    let mut i = 0;
    while i < b.len() {
        // find a type token followed by '['
        if b[i].is_ascii_alphabetic() {
            let start = i;
            while i < b.len() && (b[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            let ty = &s[start..i];
            if i < b.len() && b[i] == b'[' {
                let close = s[i..].find(']').map(|p| i + p);
                if let Some(close) = close {
                    let dims = &s[i + 1..close];
                    let n: u64 = if dims.trim().is_empty() {
                        1
                    } else {
                        dims.split(',')
                            .map(|d| d.trim().parse::<u64>().unwrap_or(1))
                            .product()
                    };
                    elems += n;
                    bytes += n * elem_size(ty);
                    i = close + 1;
                    continue;
                }
            }
        } else {
            i += 1;
        }
    }
    (elems, bytes)
}

/// Extract dot FLOPs as 2 · out_elems · K, resolving K (the contracted
/// dimension) from the first operand's recorded shape; falls back to 64
/// when the operand is unknown.
fn dot_flops(
    line: &str,
    out_elems: u64,
    last_dims: &BTreeMap<String, u64>,
) -> u64 {
    let k = line
        .find('(')
        .and_then(|p| {
            let args = &line[p + 1..];
            let end = args.find(')')?;
            let first = args[..end].split(',').next()?.trim();
            last_dims.get(first).copied()
        })
        .unwrap_or(64);
    2 * out_elems * k
}

/// Parse HLO text into a report.
pub fn analyze_text(text: &str) -> HloReport {
    let mut rep = HloReport::default();
    // name -> last dimension of its (first) result shape, for dot-K lookup.
    let mut last_dims: BTreeMap<String, u64> = BTreeMap::new();
    for line in text.lines() {
        let t = line.trim_start();
        if let Some(rest) = t.strip_prefix("HloModule ") {
            rep.module = rest.split_whitespace().next().unwrap_or("?").to_string();
            continue;
        }
        // Instruction lines: `[ROOT ]name = shape opcode(...)`.
        let t = t.strip_prefix("ROOT ").unwrap_or(t);
        let Some(eq) = t.find(" = ") else { continue };
        let name = &t[..eq];
        if name.contains(' ') {
            continue;
        }
        let rhs = &t[eq + 3..];
        // rhs = "f32[2,3]{1,0} add(x, y), ..." — shape then opcode. Tuple
        // shapes contain spaces ("(f32[2], s32[2]) sort(...)"): find the
        // matching close paren first.
        let shape_end = if rhs.starts_with('(') {
            let mut depth = 0usize;
            let mut end = 0usize;
            for (i, c) in rhs.char_indices() {
                match c {
                    '(' => depth += 1,
                    ')' => {
                        depth -= 1;
                        if depth == 0 {
                            end = i + 1;
                            break;
                        }
                    }
                    _ => {}
                }
            }
            end
        } else {
            match rhs.find(' ') {
                Some(p) => p,
                None => continue,
            }
        };
        if shape_end == 0 || shape_end >= rhs.len() {
            continue;
        }
        let shape = &rhs[..shape_end];
        let after = rhs[shape_end..].trim_start();
        let opcode: String = after
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '.')
            .collect();
        if opcode.is_empty() {
            continue;
        }
        let (out_elems, out_bytes) = parse_shape(shape);
        // Record the last dim of non-tuple results for dot-K resolution.
        if !shape.starts_with('(') {
            if let Some(lb) = shape.find('[') {
                if let Some(rb) = shape[lb..].find(']') {
                    let dims = &shape[lb + 1..lb + rb];
                    let last = dims.split(',').last().and_then(|d| d.trim().parse().ok());
                    if let Some(last) = last {
                        last_dims.insert(name.to_string(), last);
                    }
                }
            }
        }
        rep.instr_count += 1;
        *rep.by_opcode.entry(opcode.clone()).or_insert(0) += 1;
        rep.result_bytes += out_bytes;
        match opcode.as_str() {
            "dot" => {
                rep.dot_count += 1;
                rep.flops += dot_flops(after, out_elems, &last_dims);
            }
            "while" => rep.while_count += 1,
            "parameter" => rep.param_bytes += out_bytes,
            "add" | "multiply" | "subtract" | "divide" | "exponential" | "maximum"
            | "minimum" | "tanh" | "rsqrt" | "power" => {
                rep.flops += out_elems;
            }
            _ => {}
        }
    }
    rep
}

pub fn analyze_file(path: &Path) -> Result<HloReport> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    Ok(analyze_text(&text))
}

impl HloReport {
    pub fn top_opcodes(&self, n: usize) -> Vec<(String, usize)> {
        let mut v: Vec<(String, usize)> =
            self.by_opcode.iter().map(|(k, c)| (k.clone(), *c)).collect();
        v.sort_by(|a, b| b.1.cmp(&a.1));
        v.truncate(n);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::artifacts_dir;

    #[test]
    fn parses_shapes() {
        assert_eq!(parse_shape("f32[2,3]{1,0}"), (6, 24));
        assert_eq!(parse_shape("s32[]"), (1, 4));
        let (e, b) = parse_shape("(f32[4,2]{1,0}, pred[8])");
        assert_eq!(e, 16);
        assert_eq!(b, 40);
    }

    #[test]
    fn analyzes_synthetic_module() {
        let src = "HloModule demo\n\nENTRY main {\n  \
                   p0 = f32[4,8]{1,0} parameter(0)\n  \
                   p1 = f32[8,2]{1,0} parameter(1)\n  \
                   d = f32[4,2]{1,0} dot(p0, p1), lhs_contracting_dims={1}\n  \
                   ROOT a = f32[4,2]{1,0} add(d, d)\n}\n";
        let r = analyze_text(src);
        assert_eq!(r.module, "demo");
        assert_eq!(r.by_opcode["parameter"], 2);
        assert_eq!(r.dot_count, 1);
        // dot: 2 · out(8) · k(8) = 128; add: 8 elems.
        assert_eq!(r.flops, 128 + 8);
        assert_eq!(r.param_bytes, (32 + 16) * 4); // 48 f32 elems
    }

    #[test]
    fn decode_graphs_have_expected_structure() {
        let dir = artifacts_dir();
        if !dir.join("decode_full_b1.hlo.txt").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let full = analyze_file(&dir.join("decode_full_b1.hlo.txt")).unwrap();
        let loki = analyze_file(&dir.join("decode_loki_b1.hlo.txt")).unwrap();
        // One scoring dot per layer (+ QKV/out/mlp dots); Loki adds the
        // approximate-score stage and sorts but must not balloon dots.
        assert!(full.dot_count >= 4, "full dots {}", full.dot_count);
        assert!(loki.dot_count >= full.dot_count);
        assert!(loki.dot_count <= full.dot_count + 16, "loki recomputes? {} vs {}",
                loki.dot_count, full.dot_count);
        assert!(loki.by_opcode.contains_key("sort"), "loki graph needs a top-k sort");
        // The coarse-grid perf fix (§Perf iteration 2): each Pallas call
        // lowers to at most ONE single-trip while (pallas_call wrapper),
        // not B·H·(M/block) grid iterations. 2 kernels × n_layers is the
        // ceiling; the fine-grid lowering had 24× that trip count.
        let b8 = analyze_file(&dir.join("decode_loki_b8.hlo.txt")).unwrap();
        assert!(b8.while_count <= 8, "b8 while count exploded: {}", b8.while_count);
        assert!(loki.while_count <= 8, "b1 while count exploded: {}", loki.while_count);
    }
}
