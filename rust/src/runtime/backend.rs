//! The decode-backend trait: what the serving engine needs from a
//! runtime, abstracted away from PJRT.
//!
//! The coordinator schedules prefill / decode / inject over *some*
//! executor. In production that is [`RuntimeHandle`] (the channel front of
//! the thread-confined PJRT stack); in the deterministic test harness it
//! is [`super::sim::SimRuntime`], a pure-function model whose logits
//! depend only on a lane's token history. The engine is written against
//! this trait, so admission, preemption and the scheduler state machine
//! are testable hermetically — no compiled artifacts, no device.
//!
//! Contract the engine relies on (and the sim enforces):
//! * `prefill` returns one logits row per prompt, and a state whose lane
//!   order matches the prompt order;
//! * `decode` appends exactly one token per lane and returns the next
//!   logits row per lane;
//! * `inject` replaces gang lane `idx` with the (batch-1) state `lane`,
//!   consuming it;
//! * logits are a pure function of the lane's token history — this is
//!   what makes preempt-then-resume byte-identical: re-prefilling
//!   `prompt ++ produced` reconstructs the exact decode distribution.

use anyhow::Result;

use super::service::RuntimeHandle;
use super::stack::{DecodeRequest, StateId};

/// Backend abstraction over prefill/decode/inject execution.
pub trait DecodeBackend: Send {
    /// Prefill a batch of prompts into a fresh state; returns the state
    /// id and last-position logits per prompt.
    fn prefill(&self, pca: &str, prompts: Vec<Vec<i32>>) -> Result<(StateId, Vec<Vec<f32>>)>;

    /// Incrementally extend a batch-1 prefill state: `state` already
    /// holds `full[..done]`; append the next `n` tokens so it holds
    /// `full[..done + n]`. Returns the (possibly new) state id and the
    /// last-position logits row. Pass `done == 0` with `state == 0` to
    /// open a fresh chunked prefill.
    ///
    /// The default implementation *emulates* incremental prefill by
    /// freeing `state` and re-prefilling the whole prefix — correct for
    /// any history-pure backend but O(done + n) work per chunk (O(L²/c)
    /// for the full prompt). The stub-XLA `RuntimeHandle` stack rides
    /// this emulation because its compiled prefill graph has no
    /// append-to-state entry point (see ROADMAP "block-table-aware
    /// compiled path"); `SimRuntime` overrides it with a true O(n)
    /// in-place append. Under emulation, wall-clock prefill-cost
    /// observations attribute the full re-prefill to `n` chunk tokens,
    /// so the estimator's per-token prefill cost reads pessimistic for
    /// long prompts — a documented limitation, not a correctness issue.
    fn prefill_extend(
        &self,
        pca: &str,
        state: StateId,
        full: &[i32],
        done: usize,
        n: usize,
    ) -> Result<(StateId, Vec<f32>)> {
        if done > 0 {
            self.free(state);
        }
        let upto = (done + n).min(full.len());
        let (id, mut logits) = self.prefill(pca, vec![full[..upto].to_vec()])?;
        Ok((id, logits.swap_remove(0)))
    }

    /// Advance every lane of a state by one token; returns logits per lane.
    fn decode(&self, req: DecodeRequest) -> Result<Vec<Vec<f32>>>;

    /// Replace gang lane `idx` with the batch-1 state `lane`.
    fn inject(&self, gang: StateId, lane: StateId, idx: usize) -> Result<()>;

    /// Release a state (best-effort; used on engine shutdown).
    fn free(&self, id: StateId);
}

impl DecodeBackend for RuntimeHandle {
    fn prefill(&self, pca: &str, prompts: Vec<Vec<i32>>) -> Result<(StateId, Vec<Vec<f32>>)> {
        RuntimeHandle::prefill(self, pca, prompts)
    }

    fn decode(&self, req: DecodeRequest) -> Result<Vec<Vec<f32>>> {
        RuntimeHandle::decode(self, req)
    }

    fn inject(&self, gang: StateId, lane: StateId, idx: usize) -> Result<()> {
        RuntimeHandle::inject(self, gang, lane, idx)
    }

    fn free(&self, id: StateId) {
        RuntimeHandle::free(self, id)
    }
}
