//! `RuntimeStack`: the thread-confined PJRT engine.
//!
//! Owns the CPU PJRT client, lazily-compiled executables, device-resident
//! weight/PCA buffers and every live KV gang state. Decode steps feed the
//! previous step's output buffers straight back as inputs (the vendored
//! `xla` crate is patched to untuple execution results — see
//! `vendor/xla/xla_rs/xla_rs.cc`, `options.untuple_result = true`), so the
//! host only ever transfers tokens, lengths, Loki knobs and logits.

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::manifest::Manifest;

pub type StateId = u64;

/// Which decode graph to run and its runtime knobs. `d_mask` is the
/// per-layer principal-component mask (length `n_layers × head_dim`,
/// 1.0 for components used in approximate scoring); `j_sel` the number of
/// tokens granted exact attention.
#[derive(Clone, Debug)]
pub enum DecodeVariant {
    Full,
    Loki { d_mask: Vec<f32>, j_sel: i32 },
    H2o { j_sel: i32 },
    PcaAttn { d_mask: Vec<f32> },
}

impl DecodeVariant {
    pub fn graph_prefix(&self) -> &'static str {
        match self {
            DecodeVariant::Full => "decode_full",
            DecodeVariant::Loki { .. } => "decode_loki",
            DecodeVariant::H2o { .. } => "decode_h2o",
            DecodeVariant::PcaAttn { .. } => "decode_pcaattn",
        }
    }

    /// Uniform-`d_f` Loki config (the paper's main setting): keeps the
    /// leading `d_f·D` components in every layer and selects `k_f·M` slots.
    pub fn loki_fractions(man: &Manifest, k_f: f64, d_f: f64) -> Self {
        let (l, d) = (man.model.n_layers, man.model.head_dim);
        let d_keep = ((d as f64 * d_f).round() as usize).clamp(1, d);
        let mut mask = vec![0.0f32; l * d];
        for layer in 0..l {
            for c in 0..d_keep {
                mask[layer * d + c] = 1.0;
            }
        }
        let j = ((man.model.max_len as f64 * k_f).round() as i32).max(1);
        DecodeVariant::Loki { d_mask: mask, j_sel: j }
    }

    /// Exact-TopK baseline = Loki ranking with the full basis (Lemma 4.1).
    pub fn exact_topk(man: &Manifest, k_f: f64) -> Self {
        DecodeVariant::loki_fractions(man, k_f, 1.0)
    }

    /// Analytic score-path cost parameters for trace accounting:
    /// `(d_frac, j_sel)` where `d_frac` is the kept fraction of key
    /// components in the ranking pass (ones-fraction of `d_mask`; 1.0
    /// for exact scoring) and `j_sel` the exact-attention token budget
    /// (`None` when every token gets exact attention). Consumed by
    /// [`crate::attnsim::score_path_bytes`] per scheduling round.
    pub fn score_cost_params(&self) -> (f64, Option<usize>) {
        let ones_frac = |m: &[f32]| {
            if m.is_empty() {
                1.0
            } else {
                m.iter().filter(|&&x| x != 0.0).count() as f64 / m.len() as f64
            }
        };
        match self {
            DecodeVariant::Full => (1.0, None),
            DecodeVariant::Loki { d_mask, j_sel } => {
                (ones_frac(d_mask), Some((*j_sel).max(0) as usize))
            }
            // H2O ranks by accumulated attention mass — no key reads in
            // its ranking pass, so the score-scan fraction is zero.
            DecodeVariant::H2o { j_sel } => (0.0, Some((*j_sel).max(0) as usize)),
            DecodeVariant::PcaAttn { d_mask } => (ones_frac(d_mask), None),
        }
    }

    /// Variable-d_f policy (App. B.2 / Fig. 15): per-layer component
    /// counts, e.g. from per-layer explained-variance thresholds.
    pub fn loki_variable(man: &Manifest, k_f: f64, d_per_layer: &[usize]) -> Self {
        let (l, d) = (man.model.n_layers, man.model.head_dim);
        assert_eq!(d_per_layer.len(), l);
        let mut mask = vec![0.0f32; l * d];
        for (layer, &dk) in d_per_layer.iter().enumerate() {
            for c in 0..dk.clamp(1, d) {
                mask[layer * d + c] = 1.0;
            }
        }
        let j = ((man.model.max_len as f64 * k_f).round() as i32).max(1);
        DecodeVariant::Loki { d_mask: mask, j_sel: j }
    }

    pub fn h2o_fraction(man: &Manifest, k_f: f64) -> Self {
        DecodeVariant::H2o { j_sel: ((man.model.max_len as f64 * k_f).round() as i32).max(2) }
    }

    pub fn pcaattn_fraction(man: &Manifest, d_f: f64) -> Self {
        if let DecodeVariant::Loki { d_mask, .. } = Self::loki_fractions(man, 1.0, d_f) {
            DecodeVariant::PcaAttn { d_mask }
        } else {
            unreachable!()
        }
    }
}

/// A gang = one compiled batch's device-resident KV state.
pub struct GangState {
    pub batch: usize,
    pub pca: String,
    pub cache_len: Vec<i32>,
    kc: PjRtBuffer,
    vc: PjRtBuffer,
    acc: PjRtBuffer,
}

/// One decode call (host side of the graph contract).
#[derive(Clone, Debug)]
pub struct DecodeRequest {
    pub state: StateId,
    pub variant: DecodeVariant,
    pub tokens: Vec<i32>,
}

#[derive(Clone, Debug, Default)]
pub struct RuntimeStats {
    /// graph name -> (#executions, total seconds).
    // lint:allow(nondet-iter): keyed accumulation only, never iterated in-tree
    pub exec: HashMap<String, (u64, f64)>,
    // lint:allow(nondet-iter): keyed accumulation only, never iterated in-tree
    pub compile: HashMap<String, f64>,
    pub host_bytes_in: u64,
    pub host_bytes_out: u64,
}

impl RuntimeStats {
    fn record_exec(&mut self, name: &str, secs: f64) {
        let e = self.exec.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += secs;
    }
}

pub struct RuntimeStack {
    client: PjRtClient,
    pub manifest: Manifest,
    // lint:allow(nondet-iter): keyed access only (by graph name), never iterated
    exes: RefCell<HashMap<String, Rc<PjRtLoadedExecutable>>>,
    weights: Vec<PjRtBuffer>,
    // lint:allow(nondet-iter): keyed access only (by graph name), never iterated
    pca_proj: RefCell<HashMap<String, Rc<PjRtBuffer>>>,
    // lint:allow(nondet-iter): keyed access only (by StateId), never iterated
    states: RefCell<HashMap<StateId, GangState>>,
    next_id: Cell<StateId>,
    pub stats: RefCell<RuntimeStats>,
}

impl RuntimeStack {
    /// Load artifacts: manifest + weights to device; graphs compile lazily.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu().map_err(|e| anyhow!("PJRT client: {e}"))?;
        let names: Vec<&str> = manifest.param_names.iter().map(|s| s.as_str()).collect();
        let weights = PjRtBuffer::read_npz_by_name(
            dir.join(&manifest.weights_file),
            &client,
            &names,
        )
        .map_err(|e| anyhow!("loading weights: {e}"))?;
        Ok(Self {
            client,
            manifest,
            exes: RefCell::new(HashMap::new()),
            weights,
            pca_proj: RefCell::new(HashMap::new()),
            states: RefCell::new(HashMap::new()),
            next_id: Cell::new(1),
            stats: RefCell::new(RuntimeStats::default()),
        })
    }

    /// Lazily compile a graph by manifest name.
    #[allow(clippy::disallowed_methods)] // waived raw-clock site: compile timing is wall-only
    pub fn executable(&self, name: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(e) = self.exes.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.graph(name)?;
        let path = self.manifest.dir.join(&spec.file);
        // lint:allow(raw-clock): PJRT compile timing is wall-only by nature; the SimRuntime twin never compiles
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let secs = t0.elapsed().as_secs_f64();
        self.stats.borrow_mut().compile.insert(name.to_string(), secs);
        let rc = Rc::new(exe);
        self.exes.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// PCA projection buffer by calibration name (e.g. "wiki_pre").
    pub fn pca_buffer(&self, name: &str) -> Result<Rc<PjRtBuffer>> {
        if let Some(b) = self.pca_proj.borrow().get(name) {
            return Ok(b.clone());
        }
        let file = self
            .manifest
            .pca
            .get(name)
            .with_context(|| format!("unknown PCA calibration {name:?}"))?;
        let mut bufs = PjRtBuffer::read_npz_by_name(
            self.manifest.dir.join(file),
            &self.client,
            &["proj"],
        )
        .map_err(|e| anyhow!("loading pca {name}: {e}"))?;
        let rc = Rc::new(bufs.remove(0));
        self.pca_proj.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Identity "PCA" (sanity baseline: Loki over the raw key space).
    pub fn identity_pca(&self) -> Result<Rc<PjRtBuffer>> {
        if let Some(b) = self.pca_proj.borrow().get("identity") {
            return Ok(b.clone());
        }
        let m = &self.manifest.model;
        let (l, h, d) = (m.n_layers, m.n_heads, m.head_dim);
        let mut eye = vec![0.0f32; l * h * d * d];
        for li in 0..l * h {
            for i in 0..d {
                eye[li * d * d + i * d + i] = 1.0;
            }
        }
        let buf = self
            .buf_f32(&eye, &[l, h, d, d])
            .context("identity proj upload")?;
        let rc = Rc::new(buf);
        self.pca_proj.borrow_mut().insert("identity".to_string(), rc.clone());
        Ok(rc)
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.stats.borrow_mut().host_bytes_in += (data.len() * 4) as u64;
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("h2d f32: {e}"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.stats.borrow_mut().host_bytes_in += (data.len() * 4) as u64;
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("h2d i32: {e}"))
    }

    fn to_host_f32(&self, buf: &PjRtBuffer) -> Result<Vec<f32>> {
        let lit = buf.to_literal_sync().map_err(|e| anyhow!("d2h: {e}"))?;
        let v = lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e}"))?;
        self.stats.borrow_mut().host_bytes_out += (v.len() * 4) as u64;
        Ok(v)
    }

    #[allow(clippy::disallowed_methods)] // waived raw-clock site: exec timing is wall-only
    fn run(&self, name: &str, args: &[&PjRtBuffer]) -> Result<Vec<PjRtBuffer>> {
        let exe = self.executable(name)?;
        // lint:allow(raw-clock): real-hardware exec timing for perf stats; the SimRuntime twin never runs this path
        let t0 = Instant::now();
        let mut out = exe
            .execute_b(args)
            .map_err(|e| anyhow!("executing {name}: {e}"))?;
        self.stats
            .borrow_mut()
            .record_exec(name, t0.elapsed().as_secs_f64());
        if out.is_empty() || out[0].is_empty() {
            bail!("{name} produced no outputs");
        }
        Ok(out.remove(0))
    }

    /// Prefill a gang of prompts (≤ bucket size). Returns the new state id
    /// and per-lane last-position logits (`[batch][vocab]`, padded lanes
    /// hold garbage and should be ignored by the caller).
    pub fn prefill(&self, pca: &str, prompts: &[Vec<i32>]) -> Result<(StateId, Vec<Vec<f32>>)> {
        if prompts.is_empty() {
            bail!("prefill with no prompts");
        }
        let man = &self.manifest;
        let batch = man.pick_batch_bucket(prompts.len());
        if prompts.len() > batch {
            bail!("gang of {} exceeds largest bucket {batch}", prompts.len());
        }
        let longest = prompts.iter().map(|p| p.len()).max().unwrap_or(1);
        let plen = man
            .pick_prefill_bucket(longest)
            .with_context(|| format!("prompt of {longest} tokens exceeds every prefill bucket"))?;
        let graph = format!("prefill_b{batch}_p{plen}");

        let mut tokens = vec![0i32; batch * plen];
        let mut prompt_len = vec![0i32; batch];
        for (lane, p) in prompts.iter().enumerate() {
            tokens[lane * plen..lane * plen + p.len()].copy_from_slice(p);
            prompt_len[lane] = p.len() as i32;
        }
        let proj = if pca == "identity" { self.identity_pca()? } else { self.pca_buffer(pca)? };
        let tok_b = self.buf_i32(&tokens, &[batch, plen])?;
        let len_b = self.buf_i32(&prompt_len, &[batch])?;
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&proj);
        args.push(&tok_b);
        args.push(&len_b);
        let mut out = self.run(&graph, &args)?;
        // Outputs: kc, vc, acc, logits_last.
        if out.len() != 4 {
            bail!("{graph}: expected 4 outputs, got {}", out.len());
        }
        let logits_buf = out.pop().unwrap();
        let acc = out.pop().unwrap();
        let vc = out.pop().unwrap();
        let kc = out.pop().unwrap();
        let flat = self.to_host_f32(&logits_buf)?;
        let v = man.model.vocab_size;
        let logits: Vec<Vec<f32>> = (0..batch).map(|b| flat[b * v..(b + 1) * v].to_vec()).collect();

        let id = self.next_id.get();
        self.next_id.set(id + 1);
        self.states.borrow_mut().insert(
            id,
            GangState { batch, pca: pca.to_string(), cache_len: prompt_len, kc, vc, acc },
        );
        Ok((id, logits))
    }

    /// One decode step for a gang. `tokens` must have one entry per lane.
    /// Returns `[batch][vocab]` logits; the gang's device state advances.
    pub fn decode(&self, req: &DecodeRequest) -> Result<Vec<Vec<f32>>> {
        let man = &self.manifest;
        let mut states = self.states.borrow_mut();
        let st = states
            .get_mut(&req.state)
            .with_context(|| format!("unknown state {}", req.state))?;
        if req.tokens.len() != st.batch {
            bail!("decode tokens {} != batch {}", req.tokens.len(), st.batch);
        }
        if st.cache_len.iter().any(|&l| l as usize >= man.model.max_len) {
            bail!("KV cache full (max_len {})", man.model.max_len);
        }
        let graph = format!("{}_b{}", req.variant.graph_prefix(), st.batch);
        let proj = if st.pca == "identity" {
            self.identity_pca()?
        } else {
            self.pca_buffer(&st.pca)?
        };
        let len_b = self.buf_i32(&st.cache_len, &[st.batch])?;
        let tok_b = self.buf_i32(&req.tokens, &[st.batch])?;
        let (l, d) = (man.model.n_layers, man.model.head_dim);
        // Variant extras (kept alive until after execute).
        let mut extras: Vec<PjRtBuffer> = Vec::new();
        match &req.variant {
            DecodeVariant::Full => {}
            DecodeVariant::Loki { d_mask, j_sel } => {
                assert_eq!(d_mask.len(), l * d, "d_mask must be [L, D]");
                extras.push(self.buf_f32(d_mask, &[l, d])?);
                extras.push(self.buf_i32(&[*j_sel], &[])?);
            }
            DecodeVariant::H2o { j_sel } => {
                extras.push(self.buf_i32(&[*j_sel], &[])?);
            }
            DecodeVariant::PcaAttn { d_mask } => {
                assert_eq!(d_mask.len(), l * d, "d_mask must be [L, D]");
                extras.push(self.buf_f32(d_mask, &[l, d])?);
            }
        }
        let mut args: Vec<&PjRtBuffer> = self.weights.iter().collect();
        args.push(&proj);
        args.push(&st.kc);
        args.push(&st.vc);
        args.push(&st.acc);
        args.push(&len_b);
        args.push(&tok_b);
        for e in &extras {
            args.push(e);
        }
        let mut out = self.run(&graph, &args)?;
        if out.len() != 4 {
            bail!("{graph}: expected 4 outputs, got {}", out.len());
        }
        // Outputs: logits, kc, vc, acc — swap the cache buffers in place.
        st.acc = out.pop().unwrap();
        st.vc = out.pop().unwrap();
        st.kc = out.pop().unwrap();
        let logits_buf = out.pop().unwrap();
        for lane_len in st.cache_len.iter_mut() {
            *lane_len += 1;
        }
        let flat = self.to_host_f32(&logits_buf)?;
        let v = man.model.vocab_size;
        Ok((0..st.batch).map(|b| flat[b * v..(b + 1) * v].to_vec()).collect())
    }

    /// Continuous batching: replace `gang` lane `idx` with the (batch-1)
    /// state `lane`, which is consumed.
    pub fn inject(&self, gang: StateId, lane: StateId, idx: usize) -> Result<()> {
        let mut states = self.states.borrow_mut();
        let lane_st = states
            .remove(&lane)
            .with_context(|| format!("unknown lane state {lane}"))?;
        if lane_st.batch != 1 {
            states.insert(lane, lane_st);
            bail!("inject source must be a batch-1 state");
        }
        let gang_st = states
            .get_mut(&gang)
            .with_context(|| format!("unknown gang state {gang}"))?;
        if idx >= gang_st.batch {
            bail!("lane index {idx} out of range for batch {}", gang_st.batch);
        }
        if gang_st.pca != lane_st.pca {
            bail!("PCA mismatch between gang ({}) and lane ({})", gang_st.pca, lane_st.pca);
        }
        let graph = format!("inject_b{}", gang_st.batch);
        let idx_b = self.buf_i32(&[idx as i32], &[])?;
        let args: Vec<&PjRtBuffer> = vec![
            &gang_st.kc,
            &gang_st.vc,
            &gang_st.acc,
            &lane_st.kc,
            &lane_st.vc,
            &lane_st.acc,
            &idx_b,
        ];
        let mut out = self.run(&graph, &args)?;
        if out.len() != 3 {
            bail!("{graph}: expected 3 outputs, got {}", out.len());
        }
        gang_st.acc = out.pop().unwrap();
        gang_st.vc = out.pop().unwrap();
        gang_st.kc = out.pop().unwrap();
        gang_st.cache_len[idx] = lane_st.cache_len[0];
        Ok(())
    }

    pub fn free(&self, id: StateId) {
        self.states.borrow_mut().remove(&id);
    }

    pub fn state_len(&self, id: StateId) -> Option<Vec<i32>> {
        self.states.borrow().get(&id).map(|s| s.cache_len.clone())
    }

    pub fn state_batch(&self, id: StateId) -> Option<usize> {
        self.states.borrow().get(&id).map(|s| s.batch)
    }

    pub fn live_states(&self) -> usize {
        self.states.borrow().len()
    }

    /// Host copy of a PCA spectrum (`eig` array, `[L, H, D]` flattened).
    pub fn pca_eigenvalues(&self, name: &str) -> Result<(Vec<f32>, Vec<usize>)> {
        let file = self
            .manifest
            .pca
            .get(name)
            .with_context(|| format!("unknown PCA calibration {name:?}"))?;
        let lits = Literal::read_npz_by_name(self.manifest.dir.join(file), &(), &["eig"])
            .map_err(|e| anyhow!("loading eig {name}: {e}"))?;
        let lit = &lits[0];
        let shape = lit.array_shape().map_err(|e| anyhow!("{e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        Ok((lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?, dims))
    }
}
