"""L2 model correctness: prefill/decode vs the full-sequence oracle,
Lemma 4.1 invariances, variant limit cases, lane injection."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.configs import ModelConfig

CFG = ModelConfig(name="test", d_model=64, n_layers=2, n_heads=2, head_dim=16,
                  d_ff=96, max_len=48, vocab_size=64)


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(CFG, 0)
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, CFG.vocab_size, (2, 20)), jnp.int32)
    ref_logits = M.train_forward(CFG, params, toks)
    return params, toks, ref_logits


def random_orthogonal(rng):
    qs = []
    for _ in range(CFG.n_layers * CFG.n_heads):
        a = rng.standard_normal((CFG.head_dim, CFG.head_dim))
        q, _ = np.linalg.qr(a)
        qs.append(q)
    return jnp.asarray(
        np.stack(qs).reshape(CFG.n_layers, CFG.n_heads, CFG.head_dim, CFG.head_dim),
        jnp.float32)


def test_prefill_matches_oracle(setup):
    params, toks, ref_logits = setup
    proj = M.identity_proj(CFG)
    plen = jnp.array([8, 5], jnp.int32)
    _, _, _, logits_last = M.prefill(CFG, params, proj, toks[:, :8], plen)
    np.testing.assert_allclose(logits_last[0], ref_logits[0, 7], atol=1e-4)
    np.testing.assert_allclose(logits_last[1], ref_logits[1, 4], atol=1e-4)


def test_stepwise_decode_matches_oracle(setup):
    params, toks, ref_logits = setup
    proj = M.identity_proj(CFG)
    plen = jnp.array([8, 5], jnp.int32)
    kc, vc, acc, _ = M.prefill(CFG, params, proj, toks[:, :8], plen)
    cache_len = plen
    for _ in range(5):
        nxt = jnp.stack([toks[0, cache_len[0]], toks[1, cache_len[1]]])
        logits, kc, vc, acc = M.decode_full(CFG, params, proj, kc, vc, acc, cache_len, nxt)
        np.testing.assert_allclose(logits[0], ref_logits[0, cache_len[0]], atol=1e-4)
        np.testing.assert_allclose(logits[1], ref_logits[1, cache_len[1]], atol=1e-4)
        cache_len = cache_len + 1


def test_lemma41_orthogonal_invariance(setup):
    """Full attention logits are invariant to the orthogonal basis the
    cache is stored in."""
    params, toks, _ = setup
    rng = np.random.default_rng(9)
    plen = jnp.array([8, 8], jnp.int32)
    outs = []
    for proj in [M.identity_proj(CFG), random_orthogonal(rng)]:
        kc, vc, acc, _ = M.prefill(CFG, params, proj, toks[:, :8], plen)
        logits, *_ = M.decode_full(CFG, params, proj, kc, vc, acc, plen, toks[:, 8])
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], atol=2e-3)


def test_loki_limits(setup):
    """d_mask=1, j=M reduces Loki to full attention; small j changes it."""
    params, toks, _ = setup
    proj = M.identity_proj(CFG)
    plen = jnp.array([16, 16], jnp.int32)
    kc, vc, acc, _ = M.prefill(CFG, params, proj, toks[:, :16], plen)
    nxt = toks[:, 16]
    ones = jnp.ones((CFG.n_layers, CFG.head_dim), jnp.float32)
    full, *_ = M.decode_full(CFG, params, proj, kc, vc, acc, plen, nxt)
    loki_all, *_ = M.decode_loki(CFG, params, proj, kc, vc, acc, plen, nxt,
                                 ones, jnp.int32(CFG.max_len))
    np.testing.assert_allclose(full, loki_all, atol=1e-4)
    loki_k4, *_ = M.decode_loki(CFG, params, proj, kc, vc, acc, plen, nxt,
                                ones, jnp.int32(4))
    assert not np.allclose(full, loki_k4, atol=1e-3), "k=4 should differ from full"


def test_h2o_and_pcaattn_run_finite(setup):
    params, toks, _ = setup
    proj = M.identity_proj(CFG)
    plen = jnp.array([16, 12], jnp.int32)
    kc, vc, acc, _ = M.prefill(CFG, params, proj, toks[:, :16], plen)
    nxt = toks[:, 16]
    h2o_logits, _, _, acc2 = M.decode_h2o(CFG, params, proj, kc, vc, acc, plen, nxt,
                                          jnp.int32(8))
    assert np.isfinite(np.asarray(h2o_logits)).all()
    # H2O accumulators only grow.
    assert float(jnp.sum(acc2)) >= float(jnp.sum(acc)) - 1e-4
    dmask = jnp.zeros((CFG.n_layers, CFG.head_dim), jnp.float32).at[:, :4].set(1.0)
    pca_logits, *_ = M.decode_pcaattn(CFG, params, proj, kc, vc, acc, plen, nxt, dmask)
    assert np.isfinite(np.asarray(pca_logits)).all()


def test_inject_lane(setup):
    params, toks, _ = setup
    proj = M.identity_proj(CFG)
    plen = jnp.array([8, 8], jnp.int32)
    kc, vc, acc, _ = M.prefill(CFG, params, proj, toks[:, :8], plen)
    lane_plen = jnp.array([5], jnp.int32)
    lkc, lvc, lacc, _ = M.prefill(CFG, params, proj, toks[:1, :5], lane_plen)
    kc2, vc2, acc2 = M.inject_lane(kc, vc, acc, lkc, lvc, lacc, jnp.int32(1))
    np.testing.assert_allclose(kc2[:, 1], lkc[:, 0], atol=1e-6)
    np.testing.assert_allclose(kc2[:, 0], kc[:, 0], atol=1e-6)
    np.testing.assert_allclose(acc2[:, 1], lacc[:, 0], atol=1e-6)
    np.testing.assert_allclose(vc2[:, 0], vc[:, 0], atol=1e-6)


def test_param_names_cover_all_params():
    params = M.init_params(CFG, 0)
    assert sorted(M.param_names(CFG)) == sorted(params.keys())
    tup = M.params_to_tuple(CFG, params)
    back = M.tuple_to_params(CFG, tup)
    for n in params:
        assert params[n] is back[n]
