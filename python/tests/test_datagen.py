"""Corpus generator determinism and structure (the Rust side consumes
these artifacts; the SplitMix64 vector is also the cross-language
reference)."""

import numpy as np

from compile import datagen


def test_splitmix_reference_vector():
    # The same values are asserted in rust/src/util/rng.rs.
    r = datagen.SplitMix64(42)
    assert [r.next_u64() for _ in range(4)] == [
        13679457532755275413,
        2949826092126892291,
        5139283748462763858,
        6349198060258255764,
    ]


def test_corpus_is_deterministic():
    a, facts_a, pool_a = datagen.build_corpus("wiki", seed=7, target_bytes=50_000)
    b, facts_b, pool_b = datagen.build_corpus("wiki", seed=7, target_bytes=50_000)
    assert a == b
    assert [f.name for f in facts_a] == [f.name for f in facts_b]
    assert pool_a == pool_b


def test_profiles_differ():
    wiki, _, _ = datagen.build_corpus("wiki", seed=7, target_bytes=30_000)
    web, _, _ = datagen.build_corpus("web", seed=7, target_bytes=30_000)
    assert wiki != web
    # Byte histograms should differ measurably (different syllable banks).
    hw = np.bincount(np.frombuffer(wiki, np.uint8), minlength=256)
    hb = np.bincount(np.frombuffer(web, np.uint8), minlength=256)
    tv = np.abs(hw / hw.sum() - hb / hb.sum()).sum() / 2
    assert tv > 0.05, f"profiles too similar: TV {tv}"


def test_facts_are_shared_and_embedded():
    data, facts, _ = datagen.build_corpus("wiki", seed=7, target_bytes=300_000)
    text = data.decode()
    embedded = sum(1 for f in facts[:50] if f.sentence() in text)
    assert embedded >= 45, f"only {embedded}/50 facts embedded"
    # Facts are profile-independent.
    _, facts2, _ = datagen.build_corpus("book", seed=9, target_bytes=10_000)
    assert [f.value for f in facts] == [f.value for f in facts2]


def test_corpus_contains_task_patterns():
    data, _, _ = datagen.build_corpus("web", seed=7, target_bytes=200_000)
    text = data.decode()
    assert "repeat : " in text, "copy drills missing"
    assert " ; " in text
    assert "the code of " in text, "fact template missing"


def test_tokenize_round_trip():
    s = "the code of zorvik is ael-42 ."
    toks = datagen.tokenize(s.encode())
    assert datagen.detokenize(toks) == s
    assert all(0 <= t < 256 for t in toks)
