"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, including
hypothesis-driven shape/mask sweeps and both grid modes (coarse
CPU-lowering and the TPU-shaped blocked grid)."""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import (flash_decode_attend, loki_scores, ref,
                             sparq_style_scores)

SCALE = 0.125


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


def mask_from_lens(rng, b, h, m):
    lens = rng.integers(1, m + 1, size=b)
    valid = np.arange(m)[None, :] < lens[:, None]
    return jnp.asarray(np.broadcast_to(valid[:, None, :], (b, h, m)))


@pytest.mark.parametrize("block_m", [None, 64, 128])
@pytest.mark.parametrize("bhm", [(1, 1, 128), (2, 3, 256), (4, 2, 384)])
def test_loki_scores_matches_ref(block_m, bhm):
    b, h, m = bhm
    d = 32
    rng = np.random.default_rng(b * 100 + m)
    q, k = rand(rng, b, h, d), rand(rng, b, h, m, d)
    valid = mask_from_lens(rng, b, h, m)
    got = loki_scores(q, k, valid, scale=SCALE, block_m=block_m)
    want = ref.score_ref(q, k, valid, SCALE)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("block_m", [None, 64])
@pytest.mark.parametrize("bhm", [(1, 2, 128), (3, 2, 256)])
def test_flash_attend_matches_ref(block_m, bhm):
    b, h, m = bhm
    d = 16
    rng = np.random.default_rng(m)
    q, k, v = rand(rng, b, h, d), rand(rng, b, h, m, d), rand(rng, b, h, m, d)
    mask = mask_from_lens(rng, b, h, m)
    got = flash_decode_attend(q, k, v, mask, scale=SCALE, block_m=block_m)
    want, _ = ref.attend_ref(q, k, v, mask, SCALE)
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_sparq_style_matches_ref():
    rng = np.random.default_rng(7)
    b, h, m, d = 2, 4, 192, 32
    q, k = rand(rng, b, h, d), rand(rng, b, h, m, d)
    valid = mask_from_lens(rng, b, h, m)
    got = sparq_style_scores(q, k, valid, scale=SCALE)
    want = ref.score_ref(q, k, valid, SCALE)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_grid_modes_agree_with_each_other():
    rng = np.random.default_rng(11)
    b, h, m, d = 2, 2, 256, 32
    q, k, v = rand(rng, b, h, d), rand(rng, b, h, m, d), rand(rng, b, h, m, d)
    mask = mask_from_lens(rng, b, h, m)
    coarse = flash_decode_attend(q, k, v, mask, scale=SCALE, block_m=None)
    blocked = flash_decode_attend(q, k, v, mask, scale=SCALE, block_m=64)
    np.testing.assert_allclose(coarse, blocked, atol=1e-4)


def test_d_mask_equals_slicing():
    """Masking trailing PCA components == physically slicing the leading d
    (the runtime's d_mask trick)."""
    rng = np.random.default_rng(5)
    b, h, m, d, dsub = 1, 2, 128, 32, 8
    q, k = rand(rng, b, h, d), rand(rng, b, h, m, d)
    valid = jnp.ones((b, h, m), bool)
    dmask = jnp.asarray([1.0] * dsub + [0.0] * (d - dsub), jnp.float32)
    masked = loki_scores(q * dmask, k, valid, scale=SCALE)
    sliced = jnp.einsum("bhd,bhmd->bhm", q[..., :dsub], k[..., :dsub]) * SCALE
    np.testing.assert_allclose(masked, sliced, atol=1e-5)


def test_all_masked_slots_give_finite_output():
    b, h, m, d = 1, 1, 64, 8
    rng = np.random.default_rng(3)
    q, k, v = rand(rng, b, h, d), rand(rng, b, h, m, d), rand(rng, b, h, m, d)
    mask = jnp.zeros((b, h, m), bool).at[0, 0, 0].set(True)
    out = flash_decode_attend(q, k, v, mask, scale=SCALE)
    assert np.isfinite(np.asarray(out)).all()
    # With one live slot the output is exactly that slot's value row.
    np.testing.assert_allclose(out[0, 0], v[0, 0, 0], atol=1e-5)


@hypothesis.settings(deadline=None, max_examples=25)
@hypothesis.given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    m=st.sampled_from([32, 96, 128, 256]),
    d=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_scores_sweep(b, h, m, d, seed):
    rng = np.random.default_rng(seed)
    q, k = rand(rng, b, h, d), rand(rng, b, h, m, d)
    valid = mask_from_lens(rng, b, h, m)
    got = loki_scores(q, k, valid, scale=SCALE)
    want = ref.score_ref(q, k, valid, SCALE)
    np.testing.assert_allclose(got, want, atol=1e-4)


@hypothesis.settings(deadline=None, max_examples=20)
@hypothesis.given(
    b=st.integers(1, 2),
    m=st.sampled_from([64, 160, 256]),
    frac=st.floats(0.05, 1.0),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_attend_sparse_masks(b, m, frac, seed):
    """Random sparse selection masks (the Loki top-k case)."""
    h, d = 2, 16
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, b, h, d), rand(rng, b, h, m, d), rand(rng, b, h, m, d)
    mask = np.zeros((b, h, m), bool)
    for bi in range(b):
        for hi in range(h):
            n = max(1, int(m * frac))
            idx = rng.choice(m, size=n, replace=False)
            mask[bi, hi, idx] = True
    mask = jnp.asarray(mask)
    got = flash_decode_attend(q, k, v, mask, scale=SCALE)
    want, _ = ref.attend_ref(q, k, v, mask, SCALE)
    np.testing.assert_allclose(got, want, atol=1e-4)
