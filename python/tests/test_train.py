"""Training-loop smoke: a few Adam steps reduce loss on a tiny model, and
the run is deterministic given the seed."""

import numpy as np

from compile import datagen, train as T
from compile.configs import ModelConfig, TrainConfig

CFG = ModelConfig(name="smoke", d_model=48, n_layers=1, n_heads=2, head_dim=16,
                  d_ff=64, max_len=64, vocab_size=256)


def corpus():
    data, _, _ = datagen.build_corpus("wiki", seed=7, target_bytes=60_000)
    return np.frombuffer(data, np.uint8).astype(np.int32)


def test_loss_decreases():
    tcfg = TrainConfig(steps=25, seq_len=48, batch_size=4, lr=3e-3, warmup=5,
                       log_every=5)
    _, log = T.train(CFG, tcfg, corpus(), verbose=False)
    first, last = log[0]["loss"], log[-1]["loss"]
    assert last < first * 0.8, f"loss {first} -> {last}"
    assert np.isfinite(last)


def test_training_is_deterministic():
    tcfg = TrainConfig(steps=8, seq_len=32, batch_size=2, log_every=4)
    p1, _ = T.train(CFG, tcfg, corpus(), verbose=False)
    p2, _ = T.train(CFG, tcfg, corpus(), verbose=False)
    for k in p1:
        np.testing.assert_allclose(p1[k], p2[k], atol=1e-6)


def test_zero_steps_returns_init():
    tcfg = TrainConfig(steps=0)
    params, log = T.train(CFG, tcfg, corpus(), verbose=False)
    assert log == []
    assert "embed" in params
