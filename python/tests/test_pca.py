"""PCA calibration properties: orthogonality, variance recovery, Eq.-2
rank metric, and Lemma 4.2's reconstruction-optimality claim."""

import hypothesis
import hypothesis.strategies as st
import numpy as np

from compile.pca import pca_basis, rank_at


def aniso(rng, n, d, scales):
    return (rng.standard_normal((n, d)) * scales).astype(np.float32)


def test_basis_is_orthogonal_and_sorted():
    rng = np.random.default_rng(0)
    scales = 2.0 ** -np.arange(8)
    x = aniso(rng, 2000, 8, scales)[None, None]  # [1,1,N,D]
    proj, eig = pca_basis(x)
    p = proj[0, 0]
    np.testing.assert_allclose(p.T @ p, np.eye(8), atol=1e-4)
    assert (np.diff(eig[0, 0]) <= 1e-6).all(), "eigenvalues must be descending"
    np.testing.assert_allclose(eig[0, 0].sum(), 1.0, atol=1e-5)


def test_rank_at_detects_subspace():
    rng = np.random.default_rng(1)
    scales = np.full(32, 1e-3)
    scales[:3] = [3.0, 2.0, 1.0]
    x = aniso(rng, 3000, 32, scales)[None, None]
    _, eig = pca_basis(x)
    assert rank_at(eig, 90.0)[0, 0] <= 3
    assert rank_at(eig, 99.999)[0, 0] >= 3


def test_rank_at_thresholds_exact():
    eig = np.array([[[0.6, 0.3, 0.08, 0.02]]])
    assert rank_at(eig, 50.0)[0, 0] == 1
    assert rank_at(eig, 90.0)[0, 0] == 2
    assert rank_at(eig, 100.0)[0, 0] == 4


def test_lemma42_pca_minimizes_reconstruction():
    """PCA's leading-d projection reconstructs keys at least as well as
    random orthogonal d-dim projections (Lemma 4.2's optimality)."""
    rng = np.random.default_rng(2)
    d, dsub, n = 16, 4, 3000
    scales = 1.0 / (1.0 + np.arange(d))
    x = aniso(rng, n, d, scales)
    proj, _ = pca_basis(x[None, None])
    p = proj[0, 0]

    def recon_err(basis):
        b = basis[:, :dsub]
        xr = (x @ b) @ b.T
        return float(((x - xr) ** 2).sum())

    err_pca = recon_err(p)
    for trial in range(5):
        q, _ = np.linalg.qr(rng.standard_normal((d, d)))
        assert err_pca <= recon_err(q) + 1e-3, f"trial {trial}"


@hypothesis.settings(deadline=None, max_examples=15)
@hypothesis.given(d=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**16))
def test_hypothesis_rotation_preserves_dots(d, seed):
    """Lemma 4.1 at the numpy level: qᵀk == (qP)ᵀ(kP) for fitted P."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((500, d)).astype(np.float32)[None, None]
    proj, _ = pca_basis(x)
    p = proj[0, 0]
    q = rng.standard_normal(d).astype(np.float32)
    k = rng.standard_normal(d).astype(np.float32)
    np.testing.assert_allclose(q @ k, (q @ p) @ (k @ p), rtol=1e-3, atol=1e-4)
