"""Artifact-contract tests: if `make artifacts` has run, the manifest and
npz files must satisfy the invariants the Rust runtime depends on."""

import json
from pathlib import Path

import numpy as np
import pytest

ART = Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ART / "manifest.json").exists(), reason="artifacts not built")


def manifest():
    return json.loads((ART / "manifest.json").read_text())


def test_manifest_lists_existing_files():
    m = manifest()
    for g in m["graphs"].values():
        assert (ART / g["file"]).exists(), g["file"]
    assert (ART / m["weights"]).exists()
    for f in m["pca"].values():
        assert (ART / f).exists()
    assert m["default_pca"] in m["pca"]


def test_graph_io_orders():
    m = manifest()
    pn = m["param_names"]
    for b in m["batch_buckets"]:
        g = m["graphs"][f"decode_loki_b{b}"]
        assert g["inputs"][:len(pn)] == [f"params:{n}" for n in pn]
        assert g["inputs"][-2:] == ["d_mask", "j_sel"]
        assert g["outputs"] == ["logits", "kc", "vc", "acc"]
        inj = m["graphs"][f"inject_b{b}"]
        assert inj["outputs"] == ["kc", "vc", "acc"]


def test_weights_match_param_names_and_dtype():
    m = manifest()
    z = np.load(ART / m["weights"])
    assert sorted(z.files) == sorted(m["param_names"])
    for n in z.files:
        assert z[n].dtype == np.float32, n
    mdl = m["model"]
    assert z["embed"].shape == (mdl["vocab_size"], mdl["d_model"])


def test_pca_projections_are_orthogonal():
    m = manifest()
    z = np.load(ART / m["pca"][m["default_pca"]])
    proj, eig = z["proj"], z["eig"]
    L, H, D, _ = proj.shape
    mdl = m["model"]
    assert (L, H, D) == (mdl["n_layers"], mdl["n_heads"], mdl["head_dim"])
    for l in range(L):
        for h in range(H):
            p = proj[l, h]
            np.testing.assert_allclose(p.T @ p, np.eye(D), atol=1e-3)
    np.testing.assert_allclose(eig.sum(axis=-1), 1.0, atol=1e-3)
    assert (np.diff(eig, axis=-1) <= 1e-6).all()


def test_eval_docs_within_vocab():
    m = manifest()
    for prof in m["calibration_datasets"]:
        z = np.load(ART / f"eval_{prof}.npz")
        t = z["tokens"]
        assert t.ndim == 2
        assert t.min() >= 0 and t.max() < m["model"]["vocab_size"]


def test_keys_dump_shapes():
    m = manifest()
    mdl = m["model"]
    z = np.load(ART / "keys_wiki.npz")
    for kind in ["k_pre", "k_post", "q_pre", "q_post", "v"]:
        a = z[kind]
        assert a.shape[0] == mdl["n_layers"]
        assert a.shape[1] == mdl["n_heads"]
        assert a.shape[3] == mdl["head_dim"]
        assert np.isfinite(a).all()


def test_hlo_text_parses_as_text():
    m = manifest()
    g = m["graphs"]["decode_full_b1"]
    head = (ART / g["file"]).read_text()[:200]
    assert head.startswith("HloModule"), head
