"""Build-time training loop (hand-rolled Adam; optax is not in the image).

Trains the llama-style model of model.py on a synthetic corpus. Two phases:
a main phase at TrainConfig.seq_len and a short long-context phase at
cfg.max_len so RoPE sees the positions the serving cache will use (the
LongBench-analog tasks decode near max_len).

The loss curve is returned and exported to artifacts/train_log.json — it is
the end-to-end training evidence recorded in EXPERIMENTS.md.
"""

from __future__ import annotations

import functools
import time
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig, TrainConfig
from . import model as M


def batch_iterator(tokens: np.ndarray, seq_len: int, batch_size: int, seed: int):
    """Random contiguous windows of seq_len+1 tokens."""
    rng = np.random.default_rng(seed)
    n = len(tokens) - seq_len - 1
    while True:
        idx = rng.integers(0, n, batch_size)
        yield np.stack([tokens[i:i + seq_len + 1] for i in idx]).astype(np.int32)


@functools.partial(jax.jit, static_argnums=(0,))
def _adam_step(cfg: ModelConfig, params, m, v, t, batch, lr, wd, clip):
    loss, grads = jax.value_and_grad(lambda p: M.loss_fn(cfg, p, batch))(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
    b1, b2, eps = 0.9, 0.95, 1e-8
    new_params, new_m, new_v = {}, {}, {}
    for key in params:
        g = grads[key] * scale
        m_k = b1 * m[key] + (1 - b1) * g
        v_k = b2 * v[key] + (1 - b2) * g * g
        mhat = m_k / (1 - b1 ** t)
        vhat = v_k / (1 - b2 ** t)
        upd = mhat / (jnp.sqrt(vhat) + eps)
        decay = 0.0 if params[key].ndim == 1 else wd
        new_params[key] = params[key] - lr * (upd + decay * params[key])
        new_m[key] = m_k
        new_v[key] = v_k
    return new_params, new_m, new_v, loss, gnorm


def train(cfg: ModelConfig, tcfg: TrainConfig, corpus_tokens: np.ndarray,
          verbose: bool = True) -> Tuple[Dict[str, jnp.ndarray], List[dict]]:
    """Returns (params, loss log). steps == 0 returns the random init
    (the 'loki-random' control model in the Fig-1 family)."""
    params = M.init_params(cfg, tcfg.seed)
    if tcfg.steps == 0:
        return params, []
    m = {k: jnp.zeros_like(p) for k, p in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}

    # Phase split: last 15% of steps at the full cache length so positional
    # embeddings cover serving-time positions.
    long_steps = max(1, tcfg.steps * 15 // 100)
    main_steps = tcfg.steps - long_steps
    long_bs = max(1, tcfg.batch_size // 4)
    it_main = batch_iterator(corpus_tokens, tcfg.seq_len, tcfg.batch_size, tcfg.seed + 1)
    it_long = batch_iterator(corpus_tokens, min(cfg.max_len, len(corpus_tokens) // 2 - 2),
                             long_bs, tcfg.seed + 2)

    log: List[dict] = []
    t0 = time.time()
    for step in range(1, tcfg.steps + 1):
        warm = min(1.0, step / max(1, tcfg.warmup))
        # Cosine decay after warmup.
        prog = max(0.0, (step - tcfg.warmup) / max(1, tcfg.steps - tcfg.warmup))
        lr = tcfg.lr * warm * (0.5 * (1 + np.cos(np.pi * prog)))
        batch = next(it_main) if step <= main_steps else next(it_long)
        params, m, v, loss, gnorm = _adam_step(
            cfg, params, m, v, step, jnp.asarray(batch), lr, tcfg.weight_decay,
            tcfg.grad_clip)
        if step % tcfg.log_every == 0 or step == 1 or step == tcfg.steps:
            rec = {"step": step, "loss": float(loss), "lr": float(lr),
                   "grad_norm": float(gnorm), "wall_s": round(time.time() - t0, 1),
                   "phase": "main" if step <= main_steps else "long"}
            log.append(rec)
            if verbose:
                print(f"[train {cfg.name}] step {step:4d} loss {rec['loss']:.4f} "
                      f"lr {lr:.2e} |g| {rec['grad_norm']:.2f} ({rec['wall_s']}s)",
                      flush=True)
    return params, log
