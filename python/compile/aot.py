"""AOT export pipeline: python runs ONCE here, never at serve time.

`make artifacts` → this module:

  1. builds the three synthetic corpora (wiki/web/book) and exports eval
     docs + the task source material (facts/words/fillers) for the Rust
     eval harnesses;
  2. trains the main served model and the Fig-1 analysis family (with a
     random-init control), logging loss curves;
  3. calibrates PCA bases per (layer, head) on every corpus, pre- and
     post-rotary, and dumps key/query/value samples for the Rust-side
     dimensionality analysis;
  4. lowers prefill + decode-variant graphs to HLO **text** (the
     xla_extension 0.5.1 in the image rejects jax>=0.5 serialized protos —
     the text parser reassigns instruction ids; see /opt/xla-example);
  5. writes manifest.json describing every artifact and the exact
     input/output order of every graph (the Rust runtime's contract).

Re-running is cheap: if manifest.json matches the current config hash the
export exits immediately (LOKI_FORCE=1 overrides).
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, model as M, pca as P, train as T
from .configs import (ARTIFACT_VERSION, BATCH_BUCKETS, CALIBRATION_DATASETS,
                      PREFILL_BUCKETS, main_model, model_family, train_config)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def save_npz(path: Path, arrays: dict) -> None:
    np.savez(path, **{k: np.asarray(v) for k, v in arrays.items()})


def config_hash(cfg, tcfg) -> str:
    blob = json.dumps(
        {"model": dataclasses.asdict(cfg), "train": dataclasses.asdict(tcfg),
         "version": ARTIFACT_VERSION, "buckets": [BATCH_BUCKETS, PREFILL_BUCKETS]},
        sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:16]


# --------------------------------------------------------------------------
# Graph lowering
# --------------------------------------------------------------------------

F32, I32 = jnp.float32, jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_graphs(cfg, out: Path, verbose=True):
    """Lower every (graph × batch bucket) to HLO text; return manifest dict."""
    L, H, Dh, M_len, V = cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.max_len, cfg.vocab_size
    pnames = M.param_names(cfg)
    graphs = {}

    def pspecs(params_example):
        return [_spec(p.shape) for p in params_example]

    params_ex = M.params_to_tuple(cfg, M.init_params(cfg, 0))

    def emit(name, fn, specs, inputs, outputs):
        t0 = time.time()
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        (out / fname).write_text(text)
        graphs[name] = {"file": fname, "inputs": inputs, "outputs": outputs}
        if verbose:
            print(f"[aot] lowered {name}: {len(text)//1024} KiB "
                  f"({time.time()-t0:.1f}s)", flush=True)

    common_in = [f"params:{n}" for n in pnames] + ["proj", "kc", "vc", "acc",
                                                   "cache_len", "tokens"]
    cache_specs = [
        _spec((L, H, Dh, Dh)),           # proj
    ]

    for B in BATCH_BUCKETS:
        dec_specs = (list(pspecs(params_ex)) + [
            _spec((L, H, Dh, Dh)),               # proj
            _spec((L, B, H, M_len, Dh)),         # kc
            _spec((L, B, H, M_len, Dh)),         # vc
            _spec((L, B, H, M_len)),             # acc
            _spec((B,), I32),                    # cache_len
            _spec((B,), I32),                    # tokens
        ])
        dec_out = ["logits", "kc", "vc", "acc"]

        def mk(fn, *extra):
            def wrapped(*args):
                n = len(pnames)
                params = M.tuple_to_params(cfg, args[:n])
                return fn(cfg, params, *args[n:])
            return wrapped

        emit(f"decode_full_b{B}", mk(M.decode_full), dec_specs,
             common_in, dec_out)
        emit(f"decode_loki_b{B}", mk(M.decode_loki),
             dec_specs + [_spec((L, Dh)), _spec((), I32)],
             common_in + ["d_mask", "j_sel"], dec_out)
        emit(f"decode_h2o_b{B}", mk(M.decode_h2o),
             dec_specs + [_spec((), I32)],
             common_in + ["j_sel"], dec_out)
        emit(f"decode_pcaattn_b{B}", mk(M.decode_pcaattn),
             dec_specs + [_spec((L, Dh))],
             common_in + ["d_mask"], dec_out)

        for PLEN in PREFILL_BUCKETS:
            pf_specs = (list(pspecs(params_ex)) + [
                _spec((L, H, Dh, Dh)),
                _spec((B, PLEN), I32),
                _spec((B,), I32),
            ])
            emit(f"prefill_b{B}_p{PLEN}", mk(M.prefill), pf_specs,
                 [f"params:{n}" for n in pnames] + ["proj", "tokens", "prompt_len"],
                 ["kc", "vc", "acc", "logits_last"])

        # Continuous batching: swap one prefilled lane into a live gang.
        inj_specs = [
            _spec((L, B, H, M_len, Dh)), _spec((L, B, H, M_len, Dh)),
            _spec((L, B, H, M_len)),
            _spec((L, 1, H, M_len, Dh)), _spec((L, 1, H, M_len, Dh)),
            _spec((L, 1, H, M_len)),
            _spec((), I32),
        ]
        emit(f"inject_b{B}", M.inject_lane, inj_specs,
             ["kc", "vc", "acc", "lane_kc", "lane_vc", "lane_acc", "idx"],
             ["kc", "vc", "acc"])
    return graphs


# --------------------------------------------------------------------------
# Main pipeline
# --------------------------------------------------------------------------


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    cfg, tcfg = main_model(), train_config()
    chash = config_hash(cfg, tcfg)
    man_path = out / "manifest.json"
    if man_path.exists() and not os.environ.get("LOKI_FORCE"):
        try:
            if json.loads(man_path.read_text()).get("config_hash") == chash:
                print(f"[aot] artifacts up to date (hash {chash}); skipping")
                return
        except Exception:
            pass

    t_start = time.time()
    fast = bool(os.environ.get("LOKI_FAST"))
    corpus_bytes = 400_000 if fast else 2_000_000

    # ---- 1. corpora -------------------------------------------------------
    corpora, fillers = {}, {}
    facts = None
    for prof in CALIBRATION_DATASETS:
        data, fcts, pool = datagen.build_corpus(prof, seed=7, target_bytes=corpus_bytes)
        corpora[prof] = np.frombuffer(data, np.uint8).astype(np.int32)
        fillers[prof] = pool
        facts = fcts
        print(f"[aot] corpus {prof}: {len(data)} bytes", flush=True)

    # Train/eval split: last 10% of each corpus is eval-only.
    eval_docs = {}
    doc_len = min(cfg.max_len - 8, 640)
    for prof, toks in corpora.items():
        tail = toks[int(len(toks) * 0.9):]
        n_docs = 12 if not fast else 4
        docs = [tail[i * doc_len:(i + 1) * doc_len] for i in range(n_docs)]
        eval_docs[prof] = np.stack([d for d in docs if len(d) == doc_len])
        save_npz(out / f"eval_{prof}.npz", {"tokens": eval_docs[prof]})

    tasks = {
        "facts": [{"name": f.name, "value": f.value} for f in facts],
        "fact_prompt_template": "the code of {name} is",
        "fillers": {p: fillers[p][:512] for p in fillers},
        "doc_len": int(doc_len),
    }
    (out / "tasks.json").write_text(json.dumps(tasks))

    # ---- 2. training ------------------------------------------------------
    # Expensive steps (training, calibration) are reusable across graph-only
    # changes: a sidecar records the (model, train) hash they were built
    # under. LOKI_RETRAIN=1 forces a fresh run.
    train_toks = {p: t[:int(len(t) * 0.9)] for p, t in corpora.items()}
    train_hash = hashlib.sha256(json.dumps(
        {"model": dataclasses.asdict(cfg), "train": dataclasses.asdict(tcfg),
         "corpus": corpus_bytes}, sort_keys=True).encode()).hexdigest()[:16]
    sidecar = out / "train_state.json"
    reuse = (not os.environ.get("LOKI_RETRAIN")
             and sidecar.exists()
             and (out / "weights.npz").exists()
             and json.loads(sidecar.read_text()).get("train_hash") == train_hash)

    logs = {}
    if reuse:
        print("[aot] reusing trained weights + calibration (train hash match)")
        params = {n: jnp.asarray(v) for n, v in np.load(out / "weights.npz").items()}
        logs = json.loads((out / "train_log.json").read_text()) \
            if (out / "train_log.json").exists() else {}
    else:
        params, logs[cfg.name] = T.train(cfg, tcfg, train_toks["wiki"])
        save_npz(out / "weights.npz", {n: p for n, p in params.items()})

    family_dumps = {}
    for fcfg, ftcfg in model_family():
        if reuse and (out / f"family_{fcfg.name}.npz").exists():
            continue
        fparams, flog = T.train(fcfg, ftcfg, train_toks["wiki"])
        logs[fcfg.name] = flog
        caps = P.collect_calibration_tensors(
            fcfg, fparams, train_toks["wiki"],
            seq_len=min(256, ftcfg.seq_len), max_rows=2048 if not fast else 512)
        _, eig_pre = P.pca_basis(caps["k_pre"])
        _, eig_post = P.pca_basis(caps["k_post"])
        family_dumps[fcfg.name] = {
            "eig_pre": eig_pre, "eig_post": eig_post,
            "k_pre": caps["k_pre"][:, :, :512], "k_post": caps["k_post"][:, :, :512],
            "head_dim": np.int32(fcfg.head_dim),
        }
        save_npz(out / f"family_{fcfg.name}.npz", family_dumps[fcfg.name])
        print(f"[aot] family model {fcfg.name} done", flush=True)
    (out / "train_log.json").write_text(json.dumps(logs))
    sidecar.write_text(json.dumps({"train_hash": train_hash}))

    # ---- 3. PCA calibration ----------------------------------------------
    pca_entries = {}
    if reuse and all((out / f"pca_{p}_{k}.npz").exists()
                     for p in CALIBRATION_DATASETS for k in ("pre", "post")):
        pca_entries = {f"{p}_{k}": f"pca_{p}_{k}.npz"
                       for p in CALIBRATION_DATASETS for k in ("pre", "post")}
    else:
      for prof in CALIBRATION_DATASETS:
        caps = P.collect_calibration_tensors(
            cfg, params, train_toks[prof], seq_len=256,
            max_rows=8192 if not fast else 1024, seed=11)
        for kind, key in (("pre", "k_pre"), ("post", "k_post")):
            proj, eig = P.pca_basis(caps[key])
            name = f"{prof}_{kind}"
            save_npz(out / f"pca_{name}.npz", {"proj": proj, "eig": eig})
            pca_entries[name] = f"pca_{name}.npz"
        # Dump samples for Rust-side analysis (main model only, all tensors).
        n_dump = 1024 if not fast else 256
        save_npz(out / f"keys_{prof}.npz",
                 {k: v[:, :, :n_dump] for k, v in caps.items()})
        # Q/V spectra for App. Figs 12-13.
        _, eig_q = P.pca_basis(caps["q_post"])
        _, eig_v = P.pca_basis(caps["v"])
        save_npz(out / f"qv_eig_{prof}.npz", {"eig_q": eig_q, "eig_v": eig_v})
        print(f"[aot] PCA {prof} done", flush=True)

    # ---- 4. graphs --------------------------------------------------------
    graphs = lower_graphs(cfg, out)

    # ---- 5. manifest ------------------------------------------------------
    manifest = {
        "version": ARTIFACT_VERSION,
        "config_hash": chash,
        "model": dataclasses.asdict(cfg),
        "train": dataclasses.asdict(tcfg),
        "param_names": M.param_names(cfg),
        "batch_buckets": list(BATCH_BUCKETS),
        "prefill_buckets": list(PREFILL_BUCKETS),
        "graphs": graphs,
        "weights": "weights.npz",
        "pca": pca_entries,
        # Post-rotary calibration ranks top-k better for this model (pre- vs
        # post-rotary is evaluated per model, like the paper's Fig. 3).
        "default_pca": "wiki_post",
        "calibration_datasets": list(CALIBRATION_DATASETS),
        "family_models": [f.name for f, _ in model_family()],
        "tokenizer": {"kind": "byte", "vocab_size": cfg.vocab_size},
        "build_wall_s": round(time.time() - t_start, 1),
    }
    man_path.write_text(json.dumps(manifest, indent=1))
    print(f"[aot] DONE in {manifest['build_wall_s']}s -> {out}", flush=True)


if __name__ == "__main__":
    main()
