"""Synthetic corpora standing in for WikiText-2 / C4 / BookCorpus.

The paper only needs *distinct* text distributions for its calibration
generalizability claims (Fig. 6 middle) and *learnable structure* for the
quality experiments, so we generate three profiles with different word
banks, sentence statistics and fact densities. Each corpus embeds:

  * fact sentences      — "the code of <name> is <value> ."  → short-task QA
  * copy drills         — "repeat : <w1> <w2> <w3> ; <w1> <w2> <w3> ."
  * induction patterns  — "<a> <b> <a> <b> <a> <b> ."

Copy and induction are deliberately attention-bound: degrading the top-k
selection (low k_f/d_f) measurably breaks them, which is exactly the
sensitivity axis the paper's downstream tables probe.

Determinism: a local splitmix64 PRNG (no dependence on python's ``random``
module internals) so corpora are stable across python versions. The Rust
side never regenerates corpora — it consumes the exported token arrays,
facts table and filler pool from ``artifacts/``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Tiny deterministic PRNG (same algorithm as rust/src/util/rng.rs)."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def below(self, n: int) -> int:
        return self.next_u64() % n

    def choice(self, xs: Sequence):
        return xs[self.below(len(xs))]

    def uniform(self) -> float:
        return self.next_u64() / 2**64


# --------------------------------------------------------------------------
# Pseudo-word banks. Each profile uses different syllable inventories, which
# shifts the byte-level distribution (the only distribution a byte-level
# model sees).
# --------------------------------------------------------------------------

_SYLLABLES = {
    "wiki": ["tor", "ven", "al", "ker", "ion", "sta", "mer", "und", "pol", "gra",
             "tec", "his", "cen", "der", "min", "qua"],
    "web": ["zap", "klik", "wub", "go", "yo", "max", "biz", "net", "app", "top",
            "fun", "hot", "win", "big", "pro", "jet"],
    "book": ["ael", "mor", "isse", "thal", "orn", "ella", "dran", "eth", "lume",
             "sor", "ath", "wyn", "ond", "ira", "ves", "ulm"],
}


def make_words(profile: str, count: int, rng: SplitMix64, min_syl=2, max_syl=3) -> List[str]:
    syl = _SYLLABLES[profile]
    words, seen = [], set()
    while len(words) < count:
        n = min_syl + rng.below(max_syl - min_syl + 1)
        w = "".join(rng.choice(syl) for _ in range(n))
        if w not in seen:
            seen.add(w)
            words.append(w)
    return words


@dataclasses.dataclass
class Fact:
    name: str
    value: str

    def sentence(self) -> str:
        return f"the code of {self.name} is {self.value} ."

    def prompt(self) -> str:
        return f"the code of {self.name} is"


@dataclasses.dataclass
class CorpusSpec:
    profile: str
    n_words: int
    n_facts: int
    fact_repeat: int        # how many times each fact appears
    sent_len: Tuple[int, int]  # (min, max) words per filler sentence
    copy_frac: float        # fraction of sentences that are copy drills
    induction_frac: float
    doc_sents: Tuple[int, int]


SPECS: Dict[str, CorpusSpec] = {
    "wiki": CorpusSpec("wiki", 320, 192, 24, (6, 12), 0.12, 0.10, (8, 16)),
    "web": CorpusSpec("web", 256, 192, 24, (3, 7), 0.16, 0.12, (4, 10)),
    "book": CorpusSpec("book", 384, 192, 24, (9, 18), 0.08, 0.08, (12, 24)),
}

# Facts are SHARED across profiles (same name->value mapping) so that a model
# trained on one profile can be asked about them in any eval context, and so
# the calibration-dataset sweep does not change task answers.
_FACT_SEED = 0xFAC75EED


def make_facts(n: int = 192) -> List[Fact]:
    rng = SplitMix64(_FACT_SEED)
    names = make_words("book", n, rng, 2, 3)
    values = make_words("wiki", n, rng, 2, 2)
    return [Fact(names[i], values[i]) for i in range(n)]


def filler_sentence(words: List[str], spec: CorpusSpec, rng: SplitMix64) -> str:
    n = spec.sent_len[0] + rng.below(spec.sent_len[1] - spec.sent_len[0] + 1)
    return " ".join(rng.choice(words) for _ in range(n)) + " ."


def copy_drill(words: List[str], rng: SplitMix64) -> str:
    k = 3 + rng.below(3)
    ws = [rng.choice(words) for _ in range(k)]
    return "repeat : " + " ".join(ws) + " ; " + " ".join(ws) + " ."


def induction_pattern(words: List[str], rng: SplitMix64) -> str:
    a, b = rng.choice(words), rng.choice(words)
    reps = 3 + rng.below(2)
    return " ".join(f"{a} {b}" for _ in range(reps)) + " ."


def build_corpus(profile: str, seed: int, target_bytes: int) -> Tuple[bytes, List[Fact], List[str]]:
    """Returns (corpus bytes, facts, filler sentence pool)."""
    spec = SPECS[profile]
    rng = SplitMix64(seed ^ hash(profile) & MASK64)
    words = make_words(profile, spec.n_words, rng)
    facts = make_facts(spec.n_facts)

    # Pre-plan fact mentions so each fact is seen ~fact_repeat times.
    fact_queue: List[str] = []
    for f in facts:
        fact_queue.extend([f.sentence()] * spec.fact_repeat)
    # Shuffle (Fisher-Yates).
    for i in range(len(fact_queue) - 1, 0, -1):
        j = rng.below(i + 1)
        fact_queue[i], fact_queue[j] = fact_queue[j], fact_queue[i]

    pool: List[str] = []
    out: List[str] = []
    size = 0
    qi = 0
    while size < target_bytes:
        n_sents = spec.doc_sents[0] + rng.below(spec.doc_sents[1] - spec.doc_sents[0] + 1)
        doc: List[str] = []
        for _ in range(n_sents):
            u = rng.uniform()
            if u < spec.copy_frac:
                s = copy_drill(words, rng)
            elif u < spec.copy_frac + spec.induction_frac:
                s = induction_pattern(words, rng)
            elif qi < len(fact_queue) and u < spec.copy_frac + spec.induction_frac + 0.15:
                s = fact_queue[qi]
                qi += 1
            else:
                s = filler_sentence(words, spec, rng)
                if len(pool) < 4096:
                    pool.append(s)
            doc.append(s)
        text = " ".join(doc) + "\n"
        out.append(text)
        size += len(text)
    # If facts were not exhausted (small corpus), append the remainder so
    # every fact is in-distribution.
    if qi < len(fact_queue):
        rest = " ".join(fact_queue[qi:]) + "\n"
        out.append(rest)
    return "".join(out).encode("utf-8"), facts, pool


def tokenize(data: bytes) -> List[int]:
    """Byte-level tokenizer (identity). Mirrors rust/src/model/tokenizer.rs."""
    return list(data)


def detokenize(tokens: Sequence[int]) -> str:
    return bytes(int(t) & 0xFF for t in tokens).decode("utf-8", errors="replace")
