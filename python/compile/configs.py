"""Model / training / artifact configuration for the Loki reproduction.

Everything here is build-time only: the Rust coordinator reads the exported
``artifacts/manifest.json`` and never imports this module.
"""

from __future__ import annotations

import dataclasses
import os
from typing import List, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """A llama-style decoder-only transformer.

    head_dim (D) is the dimension Loki's PCA analysis applies to; we keep
    D=64 so that the paper's D=128 rank phenomenology scales down 2x.
    """

    name: str = "loki-small"
    vocab_size: int = 256  # byte-level
    d_model: int = 192
    n_layers: int = 4
    n_heads: int = 3
    head_dim: int = 64
    d_ff: int = 512
    max_len: int = 768  # static KV-cache length (M)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    @property
    def qkv_dim(self) -> int:
        return self.n_heads * self.head_dim

    def param_count(self) -> int:
        d, v, f = self.d_model, self.vocab_size, self.d_ff
        per_layer = 4 * d * self.qkv_dim + 3 * d * f + 2 * d
        return v * d + self.n_layers * per_layer + d + d * v


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    seed: int = 0
    seq_len: int = 384
    batch_size: int = 8
    # ~2 epochs over the 1.8M-token corpus: enough for fact memorization
    # and prompt-copy/induction circuits (400 steps ≈ 0.7 epochs learned
    # the templates but not retrieval — see EXPERIMENTS.md notes).
    steps: int = 900
    lr: float = 3e-3
    warmup: int = 40
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    log_every: int = 20


def _env_int(name: str, default: int) -> int:
    return int(os.environ.get(name, default))


def main_model() -> ModelConfig:
    return ModelConfig()


def train_config() -> TrainConfig:
    """Default training config; LOKI_FAST=1 shrinks everything for CI."""
    if os.environ.get("LOKI_FAST"):
        return TrainConfig(steps=_env_int("LOKI_TRAIN_STEPS", 30), seq_len=128, batch_size=4)
    return TrainConfig(steps=_env_int("LOKI_TRAIN_STEPS", 900))


def model_family() -> List[Tuple[ModelConfig, TrainConfig]]:
    """The model family for the Fig-1 style cross-model rank analysis.

    Includes a random-init control (steps=0) — keys from an *untrained*
    model should sit much closer to full rank, strengthening the paper's
    claim that training induces the low-dimensional structure.
    """
    fast = bool(os.environ.get("LOKI_FAST"))
    steps = 120 if not fast else 10
    seq = 256 if not fast else 128
    base = TrainConfig(steps=steps, seq_len=seq, batch_size=8 if not fast else 4)
    fam = [
        (ModelConfig(name="loki-tiny", d_model=128, n_layers=2, n_heads=2, d_ff=384), base),
        (ModelConfig(name="loki-wide", d_model=256, n_layers=2, n_heads=4, d_ff=512), base),
        (ModelConfig(name="loki-deep", d_model=128, n_layers=6, n_heads=2, d_ff=384), base),
        (
            ModelConfig(name="loki-random", d_model=192, n_layers=4, n_heads=3, d_ff=512),
            dataclasses.replace(base, steps=0),
        ),
    ]
    return fam


# Batch-size buckets the coordinator schedules into; one compiled executable
# per (graph, bucket).
BATCH_BUCKETS = (1, 8)
# Prefill prompt-length buckets (right-padded; per-lane true length is a
# runtime input).
PREFILL_BUCKETS = (128, 512)

CALIBRATION_DATASETS = ("wiki", "web", "book")

ARTIFACT_VERSION = 4
