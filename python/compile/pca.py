"""PCA calibration of attention keys (Section 3 / Section 4.1 of the paper).

For every (layer, head) we collect keys generated while running the model
over a calibration corpus, compute the covariance eigendecomposition, and
keep the full orthogonal basis P (columns = principal components, sorted by
descending eigenvalue). The runtime stores K̂ = K·P in the KV-cache and
approximates scores with the leading d columns.

Both pre-rotary and post-rotary keys are calibrated (the paper evaluates
both as candidate transforms; pre-rotary generalizes better for some
models). Either basis is *applied* to post-rotary keys at runtime —
Lemma 4.1 only needs orthogonality, while approximation quality (Lemma 4.2)
depends on how well the basis matches the runtime key distribution.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from . import model as M


def collect_calibration_tensors(cfg: ModelConfig, params, tokens: np.ndarray,
                                seq_len: int = 256, max_rows: int = 8192,
                                seed: int = 0) -> Dict[str, np.ndarray]:
    """Run the model over calibration windows, returning [L, H, N, Dh]
    arrays for k_pre / k_post / q_pre / q_post / v."""
    rng = np.random.default_rng(seed)
    n_batches = max(1, max_rows // (4 * seq_len))
    outs = {n: [] for n in ("k_pre", "k_post", "q_pre", "q_post", "v")}
    limit = len(tokens) - seq_len - 1
    for _ in range(n_batches):
        idx = rng.integers(0, limit, 4)
        batch = np.stack([tokens[i:i + seq_len] for i in idx]).astype(np.int32)
        caps = M.collect_keys(cfg, params, jnp.asarray(batch))
        for name, arr in caps.items():
            # [L, B, T, H, Dh] -> [L, H, B*T, Dh]
            a = np.asarray(arr)
            L, B, T, H, Dh = a.shape
            outs[name].append(a.transpose(0, 3, 1, 2, 4).reshape(L, H, B * T, Dh))
    return {n: np.concatenate(v, axis=2)[:, :, :max_rows] for n, v in outs.items()}


def pca_basis(samples: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """samples [L, H, N, Dh] -> (proj [L, H, Dh, Dh], eig [L, H, Dh]).

    proj columns are unit eigenvectors of the key covariance, sorted by
    descending eigenvalue; eig is normalized to sum to 1 (explained
    variance). Mirrors rust/src/linalg/pca.rs (cross-validated in tests).
    """
    L, H, N, Dh = samples.shape
    proj = np.zeros((L, H, Dh, Dh), np.float32)
    eig = np.zeros((L, H, Dh), np.float32)
    for l in range(L):
        for h in range(H):
            x = samples[l, h].astype(np.float64)
            x = x - x.mean(axis=0, keepdims=True)
            cov = (x.T @ x) / max(1, N - 1)
            w, v = np.linalg.eigh(cov)          # ascending
            order = np.argsort(w)[::-1]
            w, v = w[order], v[:, order]
            w = np.maximum(w, 0)
            tot = w.sum()
            eig[l, h] = (w / tot if tot > 0 else w).astype(np.float32)
            proj[l, h] = v.astype(np.float32)
    return proj, eig


def rank_at(eig: np.ndarray, v_pct: float = 90.0) -> np.ndarray:
    """Eq. 2: min d such that the first d normalized eigenvalues cover v%.

    eig [..., Dh] normalized -> int ranks [...]."""
    c = np.cumsum(eig, axis=-1)
    return 1 + np.argmax(c >= v_pct / 100.0 - 1e-12, axis=-1)
