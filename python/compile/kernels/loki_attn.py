"""L1 Pallas kernels for Loki decode attention.

Two kernels make up the hot path at generation step S:

  * ``loki_scores``    — approximate scores q̂[:, :d] · K̂[:, :d]ᵀ over the
    whole cache. The PCA basis orders components, so the d-dim slice is the
    *leading, contiguous* part of the feature axis: the HBM→VMEM schedule
    streams only ``block_m × d`` tiles (this contiguity is Loki's edge over
    SparQ, which must gather arbitrary feature columns). The 2-D grid
    (batch·head × cache blocks) is our Appendix-C fix to SparQ's 1-D grid.
  * ``flash_decode_attend`` — exact attention over the selected slots:
    single-query flash-style online softmax, one pass over cache blocks,
    running (m, l, acc) carried in VMEM scratch. The same kernel serves
    full attention (mask = live slots) and Loki's sparse step (mask = live
    ∧ selected): masked blocks still stream on CPU-interpret, but on a real
    TPU the BlockSpec index map would skip non-selected blocks — the
    bandwidth claim of the paper. See DESIGN.md §3.

interpret=True throughout: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret-mode lowers the kernels into plain HLO so the Rust
runtime can run them. Correctness vs. kernels/ref.py is enforced by pytest.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
# Cache-block width. On a real TPU this is the VMEM tiling knob (128 keeps
# tile + query + partials inside VMEM); under CPU-interpret every grid step
# executes *sequentially* inside an XLA while-loop, so the AOT path lowers
# with block_m = M (one block per lane) — set via the block_m argument by
# aot.py. See EXPERIMENTS.md §Perf for the measured effect.
DEFAULT_BLOCK_M = 128


def _score_kernel(q_ref, k_ref, valid_ref, o_ref, *, scale):
    # Blocks: q [1,1,D], k [1,1,Mb,D], valid [1,1,Mb], o [1,1,Mb].
    q = q_ref[0, 0]               # [D]
    k = k_ref[0, 0]               # [Mb, D]
    s = jnp.dot(k, q) * scale     # [Mb]
    v = valid_ref[0, 0]
    o_ref[0, 0] = jnp.where(v, s, NEG_INF)


def _score_kernel_whole(q_ref, k_ref, valid_ref, o_ref, *, scale):
    # Coarse single-step grid for CPU-interpret AOT lowering: one fused
    # einsum instead of B·H·(M/block) sequential while-loop iterations
    # (each iteration costs ~1.5 ms of dispatch overhead on the CPU PJRT
    # runtime — see EXPERIMENTS.md §Perf).
    s = jnp.einsum("bhmd,bhd->bhm", k_ref[...], q_ref[...]) * scale
    o_ref[...] = jnp.where(valid_ref[...], s, NEG_INF)


def loki_scores(q, k_cache, valid, *, scale, block_m=None,
                interpret: bool = True):
    """Approximate (or exact, if q is unmasked) scores for one decode step.

    q:       [B, H, D] — caller applies the PCA rotation and the d-mask
    k_cache: [B, H, M, D] (rotated keys)
    valid:   [B, H, M] bool (per-head: H2O's heavy-hitter sets differ by head)
    returns  [B, H, M] float32, NEG_INF on dead slots

    block_m=None lowers the coarse single-step variant (CPU-interpret
    serving artifacts); an explicit block_m lowers the TPU-shaped 2-D grid.
    """
    B, H, D = q.shape
    M = k_cache.shape[2]
    if block_m is None:
        return pl.pallas_call(
            functools.partial(_score_kernel_whole, scale=scale),
            out_shape=jax.ShapeDtypeStruct((B, H, M), jnp.float32),
            interpret=interpret,
        )(q, k_cache, valid)
    if M % block_m != 0:
        block_m = M  # single block per lane for ragged caches
    grid = (B, H, M // block_m)
    return pl.pallas_call(
        functools.partial(_score_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, m: (b, h, 0)),
            pl.BlockSpec((1, 1, block_m, D), lambda b, h, m: (b, h, m, 0)),
            pl.BlockSpec((1, 1, block_m), lambda b, h, m: (b, h, m)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_m), lambda b, h, m: (b, h, m)),
        out_shape=jax.ShapeDtypeStruct((B, H, M), jnp.float32),
        interpret=interpret,
    )(q, k_cache, valid)


def _flash_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale):
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                # [D]
    k = k_ref[0, 0]                # [Mb, D]
    v = v_ref[0, 0]                # [Mb, D]
    mask = mask_ref[0, 0]          # [Mb] bool
    s = jnp.dot(k, q) * scale      # [Mb]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[0]
    l_prev = l_ref[0]
    m_new = jnp.maximum(m_prev, jnp.max(s))
    # exp(NEG_INF - m_new) underflows to 0, so dead slots contribute nothing.
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(p, v)
    m_ref[0] = m_new
    l_ref[0] = l_new

    @pl.when(i == pl.num_programs(2) - 1)
    def _fini():
        o_ref[0, 0] = acc_ref[...] / jnp.maximum(l_ref[0], 1e-30)


def _attend_kernel_whole(q_ref, k_ref, v_ref, mask_ref, o_ref, *, scale):
    # Coarse single-step variant (see _score_kernel_whole).
    s = jnp.einsum("bhmd,bhd->bhm", k_ref[...], q_ref[...]) * scale
    s = jnp.where(mask_ref[...], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p * mask_ref[...].astype(p.dtype)
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    o_ref[...] = jnp.einsum("bhm,bhmd->bhd", p, v_ref[...])


def flash_decode_attend(q, k, v, mask, *, scale,
                        block_m=None, interpret: bool = True):
    """Single-query flash attention over masked cache slots.

    q: [B, H, D]; k, v: [B, H, M, D]; mask: [B, H, M] bool.
    returns [B, H, D].

    block_m=None lowers the coarse single-step variant (CPU-interpret
    serving artifacts); an explicit block_m lowers the TPU-shaped
    flash/online-softmax 2-D grid with VMEM scratch carries.
    """
    B, H, D = q.shape
    M = k.shape[2]
    if block_m is None:
        return pl.pallas_call(
            functools.partial(_attend_kernel_whole, scale=scale),
            out_shape=jax.ShapeDtypeStruct((B, H, D), jnp.float32),
            interpret=interpret,
        )(q, k, v, mask)
    if M % block_m != 0:
        block_m = M
    grid = (B, H, M // block_m)
    return pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h, m: (b, h, 0)),
            pl.BlockSpec((1, 1, block_m, D), lambda b, h, m: (b, h, m, 0)),
            pl.BlockSpec((1, 1, block_m, D), lambda b, h, m: (b, h, m, 0)),
            pl.BlockSpec((1, 1, block_m), lambda b, h, m: (b, h, m)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, m: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),   # running max  m
            pltpu.VMEM((1,), jnp.float32),   # running norm l
            pltpu.VMEM((D,), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v, mask)
