"""Appendix-C structural baseline: SparQ-style 1-D-parallel score kernel.

The SparQ kernels (Ribar et al., 2023) parallelize the m×k · k×n score
matmul only along the *m* dimension, which in decode attention is
proportional to batch·heads — tiny at serving batch sizes, so the GPU (or
here, the grid) is starved. Loki's Appendix C adds the n (sequence)
dimension to the grid and handles non-power-of-2 cache lengths; Figure 16
shows 2–3× gains at batch 1.

This module is the 1-D twin of ``loki_attn.loki_scores`` (identical
numerics, grid = (B·H,) instead of (B, H, M/block)). The wall-clock
comparison at real sizes is run in the Rust substrate
(rust/src/linalg/matmul.rs: ThreadedMatmul1D vs ThreadedMatmul2D,
``cargo bench --bench kernel_1d_vs_2d``); this kernel exists so the
structural difference is also visible — and tested — at the Pallas layer.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _score_kernel_1d(q_ref, k_ref, valid_ref, o_ref, *, scale):
    # One grid step owns a whole (head × cache) slab: no sequence-dimension
    # parallelism — exactly SparQ's limitation.
    q = q_ref[0, 0]                 # [D]
    k = k_ref[0, 0]                 # [M, D]
    s = jnp.dot(k, q) * scale       # [M]
    o_ref[0, 0] = jnp.where(valid_ref[0, 0], s, NEG_INF)


def sparq_style_scores(q, k_cache, valid, *, scale, interpret: bool = True):
    """Same contract as loki_attn.loki_scores, 1-D grid (B, H)."""
    B, H, D = q.shape
    M = k_cache.shape[2]
    return pl.pallas_call(
        functools.partial(_score_kernel_1d, scale=scale),
        grid=(B, H),
        in_specs=[
            pl.BlockSpec((1, 1, D), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, 1, M, D), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, M), lambda b, h: (b, h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, M), lambda b, h: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, M), jnp.float32),
        interpret=interpret,
    )(q, k_cache, valid)
