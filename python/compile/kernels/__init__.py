"""L1 Pallas kernels for Loki decode attention (build-time only).

Exports:
  loki_scores          — 2-D-grid approximate/exact score kernel
  flash_decode_attend  — single-query flash attention over masked slots
  sparq_style_scores   — 1-D-grid Appendix-C baseline
  ref                  — pure-jnp oracles
"""

from .loki_attn import flash_decode_attend, loki_scores  # noqa: F401
from .sparq_style import sparq_style_scores  # noqa: F401
from . import ref  # noqa: F401
