"""Pure-jnp oracles for the Pallas kernels.

These are the CORE correctness signal: every Pallas kernel in this package
must match its oracle to float32 tolerance (pytest enforces it, including
hypothesis-driven shape sweeps). They are also the "vanilla attention"
semantics the Rust attnsim substrate mirrors.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def score_ref(q, k_cache, valid, scale):
    """Masked dot-product scores for a single decode step.

    q:       [B, H, D]  (already PCA-rotated and d-masked by the caller)
    k_cache: [B, H, M, D]
    valid:   [B, H, M] bool — True for live cache slots
    returns  [B, H, M]
    """
    s = jnp.einsum("bhd,bhmd->bhm", q, k_cache) * scale
    return jnp.where(valid, s, NEG_INF)


def attend_ref(q, k, v, valid, scale):
    """Single-query softmax attention with slot masking.

    q: [B, H, D]; k, v: [B, H, M, D]; valid: [B, H, M] bool
    returns [B, H, D] and the post-softmax probabilities [B, H, M].
    """
    s = jnp.einsum("bhd,bhmd->bhm", q, k) * scale
    s = jnp.where(valid, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p * valid.astype(p.dtype)
    denom = jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    p = p / denom
    out = jnp.einsum("bhm,bhmd->bhd", p, v)
    return out, p


def loki_select_ref(approx_scores, j_sel):
    """Rank slots by approximate score; True for the top-j_sel slots.

    approx_scores: [B, H, M] (masked with NEG_INF on dead slots)
    j_sel: scalar int (dynamic)
    returns bool [B, H, M] selection mask.
    """
    order = jnp.argsort(-approx_scores, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    return ranks < j_sel
