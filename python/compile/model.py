"""L2: llama-style decoder-only transformer in pure JAX.

Build-time only. Three entry-point families, all of which lower to HLO text
for the Rust runtime (see aot.py):

  * ``train_forward`` / ``loss_fn``    — full causal attention, no cache
    (used by train.py; also the perplexity oracle in tests).
  * ``prefill``                        — process a (right-padded) prompt
    batch, emit the *PCA-rotated* KV cache, the H2O score accumulator and
    last-position logits.
  * ``decode_full / decode_loki / decode_h2o / decode_pcaattn``
    — one generation step over the static-shape cache. Loki's knobs are
    **runtime inputs**: ``d_mask`` ([L, D] 0/1 per-layer principal-component
    mask — equivalent to slicing the leading d components since PCA orders
    them) and ``j_sel`` (number of selected slots). One compiled graph
    therefore serves the entire (k_f, d_f) sweep, the variable-d_f policy
    (Fig. 15) and — with d_mask = 1 — the Exact-TopK baseline.

Cache layout (static shapes; M = cfg.max_len):
  kc, vc : [L, B, H, M, Dh]   — kc holds K̂ = RoPE(K) · P (rotated keys;
                                 exactness per Lemma 4.1, P orthogonal)
  acc    : [L, B, H, M]       — accumulated attention mass (H2O state)
  cache_len : [B] int32       — live slots per lane (continuous batching:
                                 lanes advance independently)

The decode attention hot path calls the L1 Pallas kernels
(kernels.loki_scores / kernels.flash_decode_attend).
"""

from __future__ import annotations

import math
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs import ModelConfig
from .kernels import flash_decode_attend, loki_scores

NEG_INF = -1e30

# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------


def param_names(cfg: ModelConfig) -> List[str]:
    """Canonical parameter order — the runtime manifest contract."""
    names = ["embed"]
    for i in range(cfg.n_layers):
        p = f"l{i:02d}"
        names += [f"{p}.norm1", f"{p}.wq", f"{p}.wk", f"{p}.wv", f"{p}.wo",
                  f"{p}.norm2", f"{p}.w1", f"{p}.w2", f"{p}.w3"]
    names += ["norm_f", "unembed"]
    return names


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jnp.ndarray]:
    rng = np.random.default_rng(seed)
    d, qkv, f, v = cfg.d_model, cfg.qkv_dim, cfg.d_ff, cfg.vocab_size

    def nrm(shape, scale):
        return jnp.asarray(rng.standard_normal(shape) * scale, jnp.float32)

    params: Dict[str, jnp.ndarray] = {"embed": nrm((v, d), 0.02)}
    for i in range(cfg.n_layers):
        p = f"l{i:02d}"
        params[f"{p}.norm1"] = jnp.ones((d,), jnp.float32)
        params[f"{p}.wq"] = nrm((d, qkv), d ** -0.5)
        params[f"{p}.wk"] = nrm((d, qkv), d ** -0.5)
        params[f"{p}.wv"] = nrm((d, qkv), d ** -0.5)
        params[f"{p}.wo"] = nrm((qkv, d), (2 * qkv * cfg.n_layers) ** -0.5)
        params[f"{p}.norm2"] = jnp.ones((d,), jnp.float32)
        params[f"{p}.w1"] = nrm((d, f), d ** -0.5)
        params[f"{p}.w2"] = nrm((f, d), (2 * f * cfg.n_layers) ** -0.5)
        params[f"{p}.w3"] = nrm((d, f), d ** -0.5)
    params["norm_f"] = jnp.ones((d,), jnp.float32)
    params["unembed"] = nrm((d, v), d ** -0.5)
    return params


def params_to_tuple(cfg: ModelConfig, params: Dict[str, jnp.ndarray]):
    return tuple(params[n] for n in param_names(cfg))


def tuple_to_params(cfg: ModelConfig, tup) -> Dict[str, jnp.ndarray]:
    return dict(zip(param_names(cfg), tup))


# --------------------------------------------------------------------------
# Primitives
# --------------------------------------------------------------------------


def rmsnorm(x, g, eps):
    return x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * g


def rope_angles(cfg: ModelConfig, positions):
    """positions [...,] -> (cos, sin) with trailing dim Dh/2."""
    half = cfg.head_dim // 2
    inv = cfg.rope_theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., Dh]; cos/sin broadcastable to [..., Dh/2]. Rotate-half form."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def swiglu(x, w1, w2, w3):
    h = jax.nn.silu(x @ w1) * (x @ w3)
    return h @ w2


def split_heads(x, n_heads, head_dim):
    # [..., H*Dh] -> [..., H, Dh] then move H before sequence axes as needed
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


# --------------------------------------------------------------------------
# Training / full-sequence forward (no cache)
# --------------------------------------------------------------------------


def train_forward(cfg: ModelConfig, params: Dict[str, jnp.ndarray], tokens):
    """tokens [B, T] -> logits [B, T, V]. Plain causal attention."""
    B, T = tokens.shape
    x = params["embed"][tokens]
    pos = jnp.arange(T)
    cos, sin = rope_angles(cfg, pos)          # [T, Dh/2]
    causal = jnp.tril(jnp.ones((T, T), bool))
    scale = 1.0 / math.sqrt(cfg.head_dim)
    for i in range(cfg.n_layers):
        p = f"l{i:02d}"
        h = rmsnorm(x, params[f"{p}.norm1"], cfg.norm_eps)
        q = split_heads(h @ params[f"{p}.wq"], cfg.n_heads, cfg.head_dim)
        k = split_heads(h @ params[f"{p}.wk"], cfg.n_heads, cfg.head_dim)
        v = split_heads(h @ params[f"{p}.wv"], cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos[:, None, :], sin[:, None, :])
        k = apply_rope(k, cos[:, None, :], sin[:, None, :])
        s = jnp.einsum("bihd,bjhd->bhij", q, k) * scale
        s = jnp.where(causal[None, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhij,bjhd->bihd", a, v).reshape(B, T, cfg.qkv_dim)
        x = x + o @ params[f"{p}.wo"]
        h = rmsnorm(x, params[f"{p}.norm2"], cfg.norm_eps)
        x = x + swiglu(h, params[f"{p}.w1"], params[f"{p}.w2"], params[f"{p}.w3"])
    x = rmsnorm(x, params["norm_f"], cfg.norm_eps)
    return x @ params["unembed"]


def loss_fn(cfg: ModelConfig, params, tokens):
    """Next-token cross entropy; tokens [B, T+1]."""
    logits = train_forward(cfg, params, tokens[:, :-1])
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def collect_keys(cfg: ModelConfig, params, tokens):
    """Forward pass that captures per-layer attention tensors.

    Returns dict with stacked [L, B, T, H, Dh] arrays:
      k_pre, k_post (pre/post-rotary keys), q_pre, q_post, v
    Used by pca.py for calibration and exported for the Rust-side
    dimensionality analysis (Figs. 1, 2, 8–13).
    """
    B, T = tokens.shape
    x = params["embed"][tokens]
    pos = jnp.arange(T)
    cos, sin = rope_angles(cfg, pos)
    causal = jnp.tril(jnp.ones((T, T), bool))
    scale = 1.0 / math.sqrt(cfg.head_dim)
    caps = {n: [] for n in ("k_pre", "k_post", "q_pre", "q_post", "v")}
    for i in range(cfg.n_layers):
        p = f"l{i:02d}"
        h = rmsnorm(x, params[f"{p}.norm1"], cfg.norm_eps)
        q = split_heads(h @ params[f"{p}.wq"], cfg.n_heads, cfg.head_dim)
        k = split_heads(h @ params[f"{p}.wk"], cfg.n_heads, cfg.head_dim)
        v = split_heads(h @ params[f"{p}.wv"], cfg.n_heads, cfg.head_dim)
        qr = apply_rope(q, cos[:, None, :], sin[:, None, :])
        kr = apply_rope(k, cos[:, None, :], sin[:, None, :])
        caps["k_pre"].append(k)
        caps["k_post"].append(kr)
        caps["q_pre"].append(q)
        caps["q_post"].append(qr)
        caps["v"].append(v)
        s = jnp.einsum("bihd,bjhd->bhij", qr, kr) * scale
        s = jnp.where(causal[None, None], s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhij,bjhd->bihd", a, v).reshape(B, T, cfg.qkv_dim)
        x = x + o @ params[f"{p}.wo"]
        h = rmsnorm(x, params[f"{p}.norm2"], cfg.norm_eps)
        x = x + swiglu(h, params[f"{p}.w1"], params[f"{p}.w2"], params[f"{p}.w3"])
    return {n: jnp.stack(v) for n, v in caps.items()}


# --------------------------------------------------------------------------
# Prefill
# --------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params, proj, tokens, prompt_len):
    """Process a right-padded prompt batch.

    proj:       [L, H, Dh, Dh] per-(layer, head) orthogonal PCA basis P
    tokens:     [B, PLEN] int32
    prompt_len: [B] int32 (true lengths; padded tail is masked out)

    Returns (kc, vc, acc, logits_last):
      kc, vc [L, B, H, M, Dh] — rotated keys / values, zero beyond the prompt
      acc    [L, B, H, M]     — column sums of prefill attention (H2O seed)
      logits_last [B, V]      — logits at each lane's final prompt token
    """
    B, T = tokens.shape
    M = cfg.max_len
    x = params["embed"][tokens]
    pos = jnp.arange(T)
    cos, sin = rope_angles(cfg, pos)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    causal = jnp.tril(jnp.ones((T, T), bool))
    lane_valid = pos[None, :] < prompt_len[:, None]          # [B, T]
    attn_mask = causal[None, None] & lane_valid[:, None, None, :]  # [B,1,T,T]

    kcs, vcs, accs = [], [], []
    for i in range(cfg.n_layers):
        p = f"l{i:02d}"
        h = rmsnorm(x, params[f"{p}.norm1"], cfg.norm_eps)
        q = split_heads(h @ params[f"{p}.wq"], cfg.n_heads, cfg.head_dim)
        k = split_heads(h @ params[f"{p}.wk"], cfg.n_heads, cfg.head_dim)
        v = split_heads(h @ params[f"{p}.wv"], cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos[:, None, :], sin[:, None, :])
        k = apply_rope(k, cos[:, None, :], sin[:, None, :])
        s = jnp.einsum("bihd,bjhd->bhij", q, k) * scale
        s = jnp.where(attn_mask, s, NEG_INF)
        a = jax.nn.softmax(s, axis=-1)
        a = a * lane_valid[:, None, :, None]  # zero rows of padded queries
        o = jnp.einsum("bhij,bjhd->bihd", a, v).reshape(B, T, cfg.qkv_dim)
        x = x + o @ params[f"{p}.wo"]
        h = rmsnorm(x, params[f"{p}.norm2"], cfg.norm_eps)
        x = x + swiglu(h, params[f"{p}.w1"], params[f"{p}.w2"], params[f"{p}.w3"])

        # Rotate keys into PCA space and pad out to the cache length.
        k_hat = jnp.einsum("bjhd,hde->bhje", k, proj[i])      # [B,H,T,Dh]
        k_hat = k_hat * lane_valid[:, None, :, None]
        v_t = jnp.transpose(v, (0, 2, 1, 3)) * lane_valid[:, None, :, None]
        pad = [(0, 0), (0, 0), (0, M - T), (0, 0)]
        kcs.append(jnp.pad(k_hat, pad))
        vcs.append(jnp.pad(v_t, pad))
        acc_l = jnp.sum(a, axis=2)                            # [B, H, T]
        accs.append(jnp.pad(acc_l, [(0, 0), (0, 0), (0, M - T)]))

    x = rmsnorm(x, params["norm_f"], cfg.norm_eps)
    logits = x @ params["unembed"]                            # [B, T, V]
    last = jnp.clip(prompt_len - 1, 0, T - 1)
    logits_last = jnp.take_along_axis(logits, last[:, None, None], axis=1)[:, 0]
    return jnp.stack(kcs), jnp.stack(vcs), jnp.stack(accs), logits_last


# --------------------------------------------------------------------------
# Decode step (shared skeleton, per-variant attention)
# --------------------------------------------------------------------------


def _rank_mask(scores, j_sel):
    """True for the j_sel highest-scoring slots (per [B, H] row)."""
    order = jnp.argsort(-scores, axis=-1)
    ranks = jnp.argsort(order, axis=-1)
    return ranks < j_sel


def _decode_skeleton(cfg: ModelConfig, params, proj, kc, vc, acc, cache_len,
                     tokens, attend_fn):
    """One decode step. attend_fn(layer, q_hat, kc_l, vc_l, acc_l, valid)
    -> (attn_out [B,H,Dh], acc_l') with valid [B,H,M] the live-slot mask."""
    B = tokens.shape[0]
    M = cfg.max_len
    x = params["embed"][tokens]                               # [B, d]
    cos, sin = rope_angles(cfg, cache_len)                    # [B, Dh/2]
    scale = 1.0 / math.sqrt(cfg.head_dim)
    slot = jnp.arange(M)
    # After appending this token at index cache_len, slots 0..cache_len live.
    valid2 = slot[None, :] <= cache_len[:, None]              # [B, M]
    valid = jnp.broadcast_to(valid2[:, None, :], (B, cfg.n_heads, M))
    write = (slot[None, :] == cache_len[:, None])[:, None, :, None]  # [B,1,M,1]

    new_kc, new_vc, new_acc = [], [], []
    for i in range(cfg.n_layers):
        p = f"l{i:02d}"
        h = rmsnorm(x, params[f"{p}.norm1"], cfg.norm_eps)
        q = split_heads(h @ params[f"{p}.wq"], cfg.n_heads, cfg.head_dim)
        k = split_heads(h @ params[f"{p}.wk"], cfg.n_heads, cfg.head_dim)
        v = split_heads(h @ params[f"{p}.wv"], cfg.n_heads, cfg.head_dim)
        q = apply_rope(q, cos[:, None, :], sin[:, None, :])   # [B, H, Dh]
        k = apply_rope(k, cos[:, None, :], sin[:, None, :])
        q_hat = jnp.einsum("bhd,hde->bhe", q, proj[i])
        k_hat = jnp.einsum("bhd,hde->bhe", k, proj[i])
        kc_l = jnp.where(write, k_hat[:, :, None, :], kc[i])  # append
        vc_l = jnp.where(write, v[:, :, None, :], vc[i])
        attn, acc_l = attend_fn(i, q_hat, kc_l, vc_l, acc[i], valid, scale)
        x = x + attn.reshape(B, cfg.qkv_dim) @ params[f"{p}.wo"]
        h = rmsnorm(x, params[f"{p}.norm2"], cfg.norm_eps)
        x = x + swiglu(h, params[f"{p}.w1"], params[f"{p}.w2"], params[f"{p}.w3"])
        new_kc.append(kc_l)
        new_vc.append(vc_l)
        new_acc.append(acc_l)

    x = rmsnorm(x, params["norm_f"], cfg.norm_eps)
    logits = x @ params["unembed"]
    return logits, jnp.stack(new_kc), jnp.stack(new_vc), jnp.stack(new_acc)


def decode_full(cfg: ModelConfig, params, proj, kc, vc, acc, cache_len, tokens):
    """Vanilla attention over the whole live cache (rotated space — exact
    by Lemma 4.1). acc passes through untouched."""

    def attend(i, q_hat, kc_l, vc_l, acc_l, valid, scale):
        out = flash_decode_attend(q_hat, kc_l, vc_l, valid, scale=scale)
        return out, acc_l

    return _decode_skeleton(cfg, params, proj, kc, vc, acc, cache_len, tokens, attend)


def decode_loki(cfg: ModelConfig, params, proj, kc, vc, acc, cache_len, tokens,
                d_mask, j_sel):
    """Loki (Algorithm 1): approximate scores on the leading principal
    components (d_mask), rank, select top-j_sel, exact attention over the
    selection. d_mask = all-ones turns this graph into the Exact-TopK
    baseline; j_sel >= M turns it into full attention."""

    def attend(i, q_hat, kc_l, vc_l, acc_l, valid, scale):
        approx = loki_scores(q_hat * d_mask[i][None, None, :], kc_l, valid,
                             scale=scale)
        sel = _rank_mask(approx, j_sel) & valid
        out = flash_decode_attend(q_hat, kc_l, vc_l, sel, scale=scale)
        return out, acc_l

    return _decode_skeleton(cfg, params, proj, kc, vc, acc, cache_len, tokens, attend)


def decode_h2o(cfg: ModelConfig, params, proj, kc, vc, acc, cache_len, tokens,
               j_sel):
    """H2O (Zhang et al.): attend over (heavy hitters ∪ recent window),
    budget split 50/50 per the authors' recommendation. Emulated as a
    masking policy over the full cache (eviction without deletion): a slot
    outside the set accrues no attention mass, so — accumulated scores
    being monotone — it can never re-enter, matching true eviction.
    acc is updated with this step's attention probabilities."""

    def attend(i, q_hat, kc_l, vc_l, acc_l, valid, scale):
        B, H, M = acc_l.shape
        slot = jnp.arange(M)
        recent_w = j_sel - j_sel // 2
        recent = slot[None, :] > (cache_len[:, None] - recent_w)   # [B, M]
        recent = jnp.broadcast_to(recent[:, None, :], (B, H, M)) & valid
        hh_scores = jnp.where(valid & ~recent, acc_l, NEG_INF)
        hh = _rank_mask(hh_scores, j_sel // 2) & valid & ~recent
        sel = recent | hh
        s = loki_scores(q_hat, kc_l, sel, scale=scale)
        p = jax.nn.softmax(s, axis=-1)
        p = p * sel.astype(p.dtype)
        p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
        out = flash_decode_attend(q_hat, kc_l, vc_l, sel, scale=scale)
        return out, acc_l + p

    return _decode_skeleton(cfg, params, proj, kc, vc, acc, cache_len, tokens, attend)


def decode_pcaattn(cfg: ModelConfig, params, proj, kc, vc, acc, cache_len,
                   tokens, d_mask):
    """Appendix E's PCAAttn: softmax directly over the d-dimensional
    approximate scores (no top-k rescue). Kept as a compiled variant to
    reproduce Table 5's failure mode."""

    def attend(i, q_hat, kc_l, vc_l, acc_l, valid, scale):
        out = flash_decode_attend(q_hat * d_mask[i][None, None, :], kc_l, vc_l,
                                  valid, scale=scale)
        return out, acc_l

    return _decode_skeleton(cfg, params, proj, kc, vc, acc, cache_len, tokens, attend)


def inject_lane(gang_kc, gang_vc, gang_acc, lane_kc, lane_vc, lane_acc, idx):
    """Continuous batching support: replace gang lane `idx` (a finished
    request's slot) with a freshly prefilled single-lane cache.

    gang_*: [L, B, H, M, Dh] / [L, B, H, M]; lane_*: [L, 1, H, M, Dh] /
    [L, 1, H, M]; idx: scalar int32. Compiled once per batch bucket as
    `inject_b{B}`; the coordinator calls it between decode iterations.
    """
    zero = jnp.int32(0)
    kc = jax.lax.dynamic_update_slice(gang_kc, lane_kc, (zero, idx, zero, zero, zero))
    vc = jax.lax.dynamic_update_slice(gang_vc, lane_vc, (zero, idx, zero, zero, zero))
    acc = jax.lax.dynamic_update_slice(gang_acc, lane_acc, (zero, idx, zero, zero))
    return kc, vc, acc


DECODE_VARIANTS = ("full", "loki", "h2o", "pcaattn")


def identity_proj(cfg: ModelConfig) -> jnp.ndarray:
    eye = jnp.eye(cfg.head_dim, dtype=jnp.float32)
    return jnp.broadcast_to(eye, (cfg.n_layers, cfg.n_heads, cfg.head_dim, cfg.head_dim))
